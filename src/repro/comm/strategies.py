"""Execute node-aware strategy stage programs on a device mesh.

:class:`IrregularExchange` takes an :class:`~repro.comm.exchange.ExchangePattern`
and a strategy name, plans the static stage program (setup time, like the
paper's Algorithm 1 / communicator construction), and exposes a jitted
``shard_map`` callable that performs the exchange:

    ``local [nranks, L]  ->  canonical recv buffer [nranks, H]``

The executor mirrors :func:`repro.comm.exchange.simulate_stage` exactly; the
symbolic simulator is the oracle for the data movement, and
``ExchangePattern.reference`` is the oracle for the delivered values.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.comm.exchange import (
    A2ALocal,
    A2APod,
    ExchangePattern,
    Gather,
    PermuteWorld,
    StagePlan,
    plan,
)
from repro.comm.topology import LOCAL_AXIS, POD_AXIS, WORLD_AXES, PodTopology, make_exchange_mesh


def _execute(stages, topo: PodTopology, local: jnp.ndarray, plan_arrays) -> jnp.ndarray:
    """Stage interpreter; runs inside shard_map. ``local`` is ``[1, L]``."""
    local = local.reshape(-1)
    buf = jnp.zeros((0,), local.dtype)
    ai = 0
    for stage in stages:
        if isinstance(stage, Gather):
            idx = plan_arrays[ai].reshape(-1)
            ai += 1
            ext = jnp.concatenate([buf, local])
            buf = ext.at[idx].get(mode="fill", fill_value=0)
        elif isinstance(stage, A2ALocal):
            buf = jax.lax.all_to_all(
                buf.reshape(topo.ppn, -1), LOCAL_AXIS, 0, 0, tiled=True
            ).reshape(-1)
        elif isinstance(stage, A2APod):
            buf = jax.lax.all_to_all(
                buf.reshape(topo.npods, -1), POD_AXIS, 0, 0, tiled=True
            ).reshape(-1)
        elif isinstance(stage, PermuteWorld):
            ext = jnp.concatenate([buf, local])
            outs = []
            for perm, blk in zip(stage.rounds, stage.blks):
                sel = plan_arrays[ai].reshape(-1)
                ai += 1
                send = ext.at[sel].get(mode="fill", fill_value=0)
                if perm:
                    outs.append(jax.lax.ppermute(send, WORLD_AXES, list(perm)))
                else:
                    outs.append(jnp.zeros_like(send))
            buf = jnp.concatenate(outs) if outs else jnp.zeros((0,), local.dtype)
        else:
            raise TypeError(f"unknown stage {stage!r}")
    return buf.reshape(1, -1)


def _plan_arrays(stage_plan: StagePlan) -> Tuple[np.ndarray, ...]:
    arrs = []
    for stage in stage_plan.stages:
        if isinstance(stage, Gather):
            arrs.append(stage.idx)
        elif isinstance(stage, PermuteWorld):
            arrs.extend(stage.sels)
    return tuple(arrs)


@dataclasses.dataclass
class IrregularExchange:
    """A planned, compiled irregular exchange for one strategy.

    Args:
      pattern: the element-level communication pattern.
      strategy: "standard" | "two_step" | "three_step" | "split".
      mesh: optional pre-built ``("pod", "local")`` mesh.
      message_cap_bytes: Split's user cap (Algorithm 1 input).
      elem_bytes: element width used for cap arithmetic / byte accounting.
    """

    pattern: ExchangePattern
    strategy: str
    mesh: Optional[jax.sharding.Mesh] = None
    message_cap_bytes: int = 16384
    elem_bytes: int = 4

    def __post_init__(self) -> None:
        self.plan: StagePlan = plan(
            self.strategy,
            self.pattern,
            message_cap_bytes=self.message_cap_bytes,
            elem_bytes=self.elem_bytes,
        )
        if self.mesh is None:
            self.mesh = make_exchange_mesh(self.pattern.topo)
        topo = self.pattern.topo
        arrays = _plan_arrays(self.plan)
        specs = (P(WORLD_AXES),) * (1 + len(arrays))

        def run(local, *plan_arrays):
            return _execute(self.plan.stages, topo, local, plan_arrays)

        self._arrays = tuple(jnp.asarray(a) for a in arrays)
        self._fn = jax.jit(
            jax.shard_map(run, mesh=self.mesh, in_specs=specs, out_specs=P(WORLD_AXES))
        )

    # ------------------------------------------------------------------
    def __call__(self, local: jax.Array) -> jax.Array:
        """``local [nranks, L] -> canonical recv [nranks, H]``."""
        if local.shape != (self.pattern.topo.nranks, self.pattern.local_size):
            raise ValueError(
                f"expected [{self.pattern.topo.nranks}, {self.pattern.local_size}], "
                f"got {local.shape}"
            )
        return self._fn(local, *self._arrays)

    # ------------------------------------------------------------------
    def reference(self, local: np.ndarray) -> np.ndarray:
        return self.pattern.reference(local)

    @property
    def wire_bytes(self) -> Tuple[int, int]:
        """(intra-pod, inter-pod) bytes on the wire incl. padding."""
        return (self.plan.wire_intra_pod_bytes, self.plan.wire_inter_pod_bytes)

    @property
    def payload_bytes(self) -> Tuple[int, int]:
        """(intra-pod, inter-pod) useful payload bytes."""
        return (self.plan.intra_pod_bytes, self.plan.inter_pod_bytes)


STRATEGY_NAMES = ("standard", "two_step", "three_step", "split")
