"""Execute node-aware strategy stage programs on a device mesh.

:class:`IrregularExchange` takes an :class:`~repro.comm.exchange.ExchangePattern`
and a strategy name, plans the static stage program (setup time, like the
paper's Algorithm 1 / communicator construction), fuses it
(:mod:`repro.comm.fusion`), and exposes a jitted ``shard_map`` callable that
performs the exchange:

    ``local [nranks, L]       ->  canonical recv buffer [nranks, H]``
    ``local [nranks, L, k...] ->  [nranks, H, k...]``  (batched payloads:
    multi-vector SpMM columns, per-token feature dims for MoE routing)

The executor mirrors :func:`repro.comm.exchange.simulate_stage` exactly; the
symbolic simulator is the oracle for the data movement, and
``ExchangePattern.reference`` is the oracle for the delivered values.

Setup cost is amortized twice over:

* **ext-once execution** -- at compile time every stage's indices are
  re-based onto a single ``[local | buf]`` scratch array allocated once per
  call, so no stage re-concatenates ``[buf, local]``.
* **plan/compile caches** -- module-level LRU caches keyed by
  ``(pattern fingerprint, strategy, message_cap, elem_bytes, fused)`` (plans)
  plus the mesh identity (executors).  Repeated ``IrregularExchange``
  constructions for the same exchange (every SpMV / MoE step) reuse the
  planned program and the jitted callable; per-``(dtype, payload shape)``
  specializations live in ``jax.jit``'s trace cache under that callable.
  Inspect with :func:`cache_stats`, reset with :func:`clear_caches`.
"""

from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.comm import compression
from repro.comm import faults as faults_mod
from repro.comm import wire as wire_mod
from repro.comm.exchange import (
    A2ALocal,
    A2APod,
    ExchangePattern,
    Gather,
    LoweredProgram,
    PermuteWorld,
    SplitPhase,
    StagePlan,
    lower_program,
    plan,
    rebase_indices,
    split_phase,
)
from repro.comm.fusion import fuse
from repro.comm.topology import (
    LOCAL_AXIS,
    POD_AXIS,
    WORLD_AXES,
    PodTopology,
    make_exchange_mesh,
)

# ---------------------------------------------------------------------------
# Compiled-program representation (ext-once execution)
# ---------------------------------------------------------------------------


#: kept as the module-local spelling of the lowering the executor was built
#: around; the canonical implementation now lives with the stage dataclasses
#: (:func:`repro.comm.exchange.lower_program`)
_rebase = rebase_indices


def _compile_program(sp: StagePlan) -> Tuple[Tuple, Tuple[np.ndarray, ...], int]:
    """Lower a stage program to executor ops + re-based index arrays.

    Back-compat tuple view of :func:`repro.comm.exchange.lower_program`:
    returns ``(ops, arrays, W_max)`` where every index array addresses the
    ``[local | buf]`` scratch of width ``L + W_max`` directly.
    """
    lp = lower_program(sp)
    return lp.ops, lp.arrays, lp.w_max


def _encode_blocks(blocks, codec: str):
    """Encode leading-axis wire blocks for an inter-pod collective.

    Returns ``(payload, aux)`` where ``aux`` is the per-block float32 scale
    for the int8 codec (shipped through the same collective) or ``None``.
    Only called when :func:`repro.comm.wire.applies` said yes.
    """
    if codec in ("bf16", "f16"):
        # saturate finite overflow only; true inf/nan propagate through the
        # cast (mirrors wire.roundtrip_np)
        wdt = jnp.bfloat16 if codec == "bf16" else jnp.float16
        fmax = float(jnp.finfo(wdt).max)
        sat = jnp.where(
            jnp.isfinite(blocks), jnp.clip(blocks, -fmax, fmax), blocks
        )
        return sat.astype(wdt), None
    # int8: one scale per leading-axis block, shared quantizer core
    # (finite-aware scale + reserved-code non-finite handling live in
    # repro.comm.compression; wire.roundtrip_np is the numpy oracle)
    f = blocks.astype(jnp.float32)
    amax = compression.finite_amax(f, axis=tuple(range(1, f.ndim)))
    scale = compression.int8_scale(amax, wire_mod.QMAX)
    bshape = (-1,) + (1,) * (f.ndim - 1)
    q = compression.int8_quantize(
        f, scale.reshape(bshape), wire_mod.QMAX, nonfinite_code=wire_mod.INT8_NONFINITE
    )
    return q, scale


def _decode_blocks(payload, aux, dtype):
    """Inverse of :func:`_encode_blocks` after the collective moved it."""
    if aux is None:
        return payload.astype(dtype)
    return compression.int8_dequantize(
        payload,
        aux.reshape((-1,) + (1,) * (payload.ndim - 1)),
        nonfinite_code=wire_mod.INT8_NONFINITE,
    ).astype(dtype)


def _wire_check(x, axes):
    """Device twin of :func:`repro.comm.faults.block_check_np`: the
    ``(sum |finite x|, nonfinite count, finite amax)`` triple per wire
    block, stacked on a trailing axis (``[..., 3]`` float32)."""
    f = x.astype(jnp.float32)
    finite = jnp.isfinite(f)
    mag = jnp.where(finite, jnp.abs(f), jnp.float32(0.0))
    s = jnp.sum(mag, axis=axes)
    c = jnp.sum((~finite).astype(jnp.float32), axis=axes)
    a = jnp.max(mag, axis=axes, initial=0.0)
    return jnp.stack([s, c, a], axis=-1)


def _check_violation(chk_pre, chk_moved_post, nelem: int, codec: str, encoded: bool):
    """Device twin of :func:`repro.comm.faults.check_violation`, reduced to
    one scalar per hop (the max violation over this shard's blocks)."""
    s0, c0, a0 = chk_pre[..., 0], chk_pre[..., 1], chk_pre[..., 2]
    s1, c1 = chk_moved_post[..., 0], chk_moved_post[..., 1]
    tol = faults_mod.sum_tolerance(codec, nelem, a0, s0, encoded)
    drift = jnp.abs(s1 - s0) - tol
    viol = jnp.where(c1 != c0, jnp.float32(jnp.inf), drift.astype(jnp.float32))
    return jnp.max(viol) if viol.ndim else viol


def _apply_injection(x, mask, kind: str, value: float):
    """Device twin of :func:`repro.comm.faults.apply_injection_np`."""
    m = mask.reshape(mask.shape + (1,) * (x.ndim - mask.ndim))
    if kind == "zero":
        return jnp.where(m, jnp.zeros((), x.dtype), x)
    if kind == "corrupt":
        return jnp.where(m, jnp.asarray(value, x.dtype), x)
    if kind == "perturb":
        return jnp.where(m, x * jnp.asarray(value, x.dtype), x)
    raise ValueError(f"unknown injection kind {kind!r}")


def _execute(
    ops,
    topo: PodTopology,
    L: int,
    w_max: int,
    out_size: int,
    local,
    plan_arrays,
    codec: str = "none",
    verify: bool = False,
    fault_ops: Optional[Dict] = None,
):
    """Ops interpreter; runs inside shard_map.  ``local`` is ``[1, L, *feat]``.

    The scratch ``ext = [local | buf]`` is built with ONE fused pad per call
    (no zeros buffer is materialized); stages read/write the buf region in
    place instead of re-concatenating ``[buf, local]`` per round.

    ``codec`` is the inter-pod wire format (:mod:`repro.comm.wire`): the
    payload of an ``A2APod`` (off-diagonal blocks) or an inter-pod
    ``PermuteWorld`` round is encoded right before the collective and
    decoded right after it.  On-pod hops and the ``"none"`` codec run the
    exact full-precision ops -- bitwise identical to the codec-free
    executor.

    ``verify`` ships the :func:`_wire_check` triple of every inter-pod
    payload through the same collective and recomputes it after
    decode+injection; the per-hop max violations are returned alongside the
    output.  ``fault_ops`` maps ``(op index, permute round | None)`` to
    ``(kind, dev_mask, value)`` injections (compiled by
    :func:`repro.comm.faults.compile_faults`); each mask is indexed by this
    shard's world rank and applied to the decoded receive blocks, mirroring
    :func:`repro.comm.exchange.execute_numpy` bitwise.

    Returns ``(out [1, out_size, *feat], viols)`` where ``viols`` is a list
    of per-hop violation scalars (empty unless ``verify``).
    """
    x = local[0]
    feat = x.shape[1:]
    ext = jnp.pad(x, ((0, w_max),) + ((0, 0),) * len(feat))
    encode = codec != "none" and wire_mod.applies(codec, x.dtype)
    viols = []
    rank = None
    if fault_ops:
        rank = jax.lax.axis_index(POD_AXIS) * topo.ppn + jax.lax.axis_index(LOCAL_AXIS)
    ai = 0
    for op_i, op in enumerate(ops):
        kind = op[0]
        if kind == "gather":
            _, width = op
            idx = plan_arrays[ai][0]
            ai += 1
            vals = ext.at[idx].get(mode="fill", fill_value=0)
            ext = ext.at[L : L + width].set(vals)
        elif kind in ("a2a_local", "a2a_pod"):
            _, buflen, has_idx = op
            if has_idx:
                idx = plan_arrays[ai][0]
                ai += 1
                seg = ext.at[idx].get(mode="fill", fill_value=0)
            else:
                seg = ext[L : L + buflen]
            groups, axis = (
                (topo.ppn, LOCAL_AXIS)
                if kind == "a2a_local"
                else (topo.npods, POD_AXIS)
            )
            blocks = seg.reshape((groups, buflen // groups) + feat)
            check = verify and kind == "a2a_pod"
            if check:
                chk = _wire_check(blocks, tuple(range(1, blocks.ndim)))
                chk_moved = jax.lax.all_to_all(chk, axis, 0, 0, tiled=True)
            if kind == "a2a_pod" and encode:
                payload, aux = _encode_blocks(blocks, codec)
                moved = jax.lax.all_to_all(payload, axis, 0, 0, tiled=True)
                if aux is not None:
                    aux = jax.lax.all_to_all(aux, axis, 0, 0, tiled=True)
                res = _decode_blocks(moved, aux, x.dtype)
                # the own-pod block never crossed DCI: the all_to_all self
                # slot holds this rank's own send block, so restore it at
                # full precision
                me = jax.lax.axis_index(axis)
                keep = (jnp.arange(groups) == me).reshape(
                    (groups,) + (1,) * (blocks.ndim - 1)
                )
                res = jnp.where(keep, blocks, res)
            else:
                res = jax.lax.all_to_all(blocks, axis, 0, 0, tiled=True)
            if kind == "a2a_pod" and fault_ops:
                for fkind, mask, value in fault_ops.get((op_i, None), ()):
                    res = _apply_injection(res, mask[rank], fkind, value)
            if check:
                chk_post = _wire_check(res, tuple(range(1, res.ndim)))
                nelem = int(np.prod(blocks.shape[1:], dtype=np.int64))
                viols.append(
                    _check_violation(chk_moved, chk_post, nelem, codec, encode)
                )
            ext = ext.at[L : L + buflen].set(res.reshape((buflen,) + feat))
        elif kind == "permute":
            _, rounds, blks, inters = op
            parts = []
            for ri, (perm, blk, inter) in enumerate(zip(rounds, blks, inters)):
                sel = plan_arrays[ai][0]
                ai += 1
                send = ext.at[sel].get(mode="fill", fill_value=0)
                if not perm:
                    parts.append(jnp.zeros_like(send))
                    continue
                check = verify and inter
                if check:
                    chk = _wire_check(send, tuple(range(send.ndim)))
                    chk_moved = jax.lax.ppermute(chk, WORLD_AXES, list(perm))
                if inter and encode:
                    payload, aux = _encode_blocks(send[None], codec)
                    moved = jax.lax.ppermute(payload[0], WORLD_AXES, list(perm))
                    if aux is not None:
                        aux = jax.lax.ppermute(aux[0], WORLD_AXES, list(perm))
                        aux = aux[None]
                    part = _decode_blocks(moved[None], aux, x.dtype)[0]
                else:
                    part = jax.lax.ppermute(send, WORLD_AXES, list(perm))
                if fault_ops:
                    for fkind, mask, value in fault_ops.get((op_i, ri), ()):
                        part = _apply_injection(part, mask[rank], fkind, value)
                if check:
                    chk_post = _wire_check(part, tuple(range(part.ndim)))
                    nelem = int(np.prod(send.shape, dtype=np.int64))
                    viols.append(
                        _check_violation(
                            chk_moved, chk_post, nelem, codec, inter and encode
                        )
                    )
                parts.append(part)
            width = sum(blks)
            if parts:
                ext = ext.at[L : L + width].set(jnp.concatenate(parts))
        else:
            raise TypeError(f"unknown op {op!r}")
    return ext[L : L + out_size][None], viols


# ---------------------------------------------------------------------------
# Traceable exchange programs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TraceableExchange:
    """A planned exchange as a first-class traceable program value.

    The pair the whole-solve path closes over inside ``jit``: a pytree of
    plan arrays (:attr:`plan_arrays`, one ``[nranks, ...]`` int32 array per
    lowered index table -- fed through ``shard_map`` input specs like any
    payload) plus the pure per-shard callable :meth:`run`.  Everything else
    on the instance is static Python data (opcodes, topology, codec,
    integrity-check metadata) that traces into the program as constants, so
    a ``TraceableExchange`` can sit inside a ``lax.while_loop`` body, a
    scanned pipeline stage, or the barrier executor alike -- the jitted
    executor of :class:`IrregularExchange` is now just ``shard_map(run)``.

    Build one with :func:`traceable_exchange` (or
    :meth:`IrregularExchange.traceable`).

    ``verify=True`` programs expose :meth:`run_verified`, which additionally
    returns the per-DCI-hop max-violation vector (``[n_checks]`` float32, in
    :attr:`checks` order) computed by the same wire integrity checks as the
    host path; callers surface positives as
    :class:`repro.comm.faults.ExchangeIntegrityError` via :meth:`raise_viols`.
    """

    lowered: LoweredProgram
    topo: PodTopology
    strategy: str
    codec: str = "none"
    #: integrity-check metadata: ``checks[j] = (ordinal, op_index,
    #: stage_kind, round_index)`` names the DCI hop behind violation column j
    checks: Tuple[tuple, ...] = ()
    #: True when :meth:`run_verified` emits a violation vector (verify was
    #: requested AND the plan has DCI-crossing hops)
    emit_checks: bool = False
    #: compiled fault injections keyed ``(op_index, round_index)`` (static:
    #: baked into the trace; a fused loop applies them on every iteration)
    fault_ops: Optional[Dict] = None
    delay_s: float = 0.0
    #: device copies of ``lowered.arrays`` -- THE plan-array pytree
    plan_arrays: Tuple[jax.Array, ...] = ()

    @property
    def out_size(self) -> int:
        return self.lowered.out_size

    @property
    def local_size(self) -> int:
        return self.lowered.local_size

    def run(self, local, *plan_arrays):
        """Pure per-shard exchange: ``local [1, L, *feat] -> [1, H, *feat]``.

        Runs inside ``shard_map`` (directly or nested in a traced loop);
        ``plan_arrays`` are the per-shard slices of :attr:`plan_arrays`.
        """
        out, _ = _execute(
            self.lowered.ops, self.topo, self.lowered.local_size,
            self.lowered.w_max, self.lowered.out_size, local, plan_arrays,
            self.codec, verify=False, fault_ops=self.fault_ops,
        )
        return out

    def run_verified(self, local, *plan_arrays):
        """Like :meth:`run` but returns ``(out, viols [n_checks] f32)``.

        With :attr:`emit_checks` False the violation vector is empty.
        """
        out, viols = _execute(
            self.lowered.ops, self.topo, self.lowered.local_size,
            self.lowered.w_max, self.lowered.out_size, local, plan_arrays,
            self.codec, verify=self.emit_checks, fault_ops=self.fault_ops,
        )
        if viols:
            return out, jnp.stack(viols)
        return out, jnp.zeros((0,), jnp.float32)

    def raise_viols(self, viols: np.ndarray) -> None:
        """Raise :class:`~repro.comm.faults.ExchangeIntegrityError` for the
        first positive column of a gathered ``[..., n_checks]`` violation
        array -- the same structured fields as the host executor's raise."""
        viols = np.asarray(viols).reshape(-1, len(self.checks))
        bad = (viols > 0.0).any(axis=0)
        if not bad.any():
            return
        j = int(np.argmax(bad))
        _, op_index, stage_kind, round_index = self.checks[j]
        raise faults_mod.ExchangeIntegrityError(
            strategy=self.strategy,
            codec=self.codec,
            stage_kind=stage_kind,
            op_index=op_index,
            round_index=round_index,
            violation=float(viols[:, j].max()),
        )


def traceable_exchange(
    sp: StagePlan,
    codec: str = "none",
    verify: bool = False,
    faults: Optional[faults_mod.FaultPlan] = None,
) -> TraceableExchange:
    """Lower a planned stage program to its traceable program value.

    This is the programmatic form of what :func:`_executor` wraps in
    ``shard_map`` for the barrier path; fused consumers
    (:mod:`repro.solve.fused`) embed :meth:`TraceableExchange.run` directly
    inside their own traced loops instead.
    """
    lp = lower_program(sp)
    checks = tuple(
        (ordinal, op_index, stage_kind, round_index)
        for ordinal, op_index, stage_kind, round_index, _, _ in (
            faults_mod.iter_inter_hops(sp)
        )
    )
    fault_ops: Optional[Dict] = None
    delay_s = 0.0
    if faults is not None:
        cf = faults_mod.compile_faults(sp, codec, faults)
        delay_s = cf.delay_s
        grouped: Dict[tuple, list] = {}
        for inj in cf.injections:
            grouped.setdefault((inj.op_index, inj.round_index), []).append(
                (inj.kind, jnp.asarray(inj.dev_mask), inj.value)
            )
        fault_ops = {k: tuple(v) for k, v in grouped.items()} or None
    return TraceableExchange(
        lowered=lp,
        topo=sp.pattern.topo,
        strategy=sp.strategy,
        codec=codec,
        checks=checks,
        emit_checks=verify and bool(checks),
        fault_ops=fault_ops,
        delay_s=delay_s,
        plan_arrays=tuple(jnp.asarray(a) for a in lp.arrays),
    )


# ---------------------------------------------------------------------------
# Plan / executor caches
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CacheStats:
    plan_hits: int = 0
    plan_misses: int = 0
    exec_hits: int = 0
    exec_misses: int = 0
    #: local-compute compile cache (repro.sparse.spmv SpMV/SpMM programs,
    #: keyed by (pattern fingerprint, payload width k, ...))
    compute_hits: int = 0
    compute_misses: int = 0
    #: split-phase decomposition + jitted-merge cache (``_SPLIT_CACHE``,
    #: keyed by pattern fingerprint; populated by ``IrregularExchange.start``
    #: and the solver's overlapped numpy executor)
    split_hits: int = 0
    split_misses: int = 0
    #: whole-instance front door used by per-batch pattern producers
    #: (:func:`exchange_for`); a hit means zero planning work for the batch
    exchange_hits: int = 0
    exchange_misses: int = 0
    #: fused whole-solve programs (``_FUSED_CACHE``: one jitted
    #: ``lax.while_loop`` Krylov solve per (pattern, strategy, codec, dtype,
    #: ...); populated by :mod:`repro.solve.fused`).  A miss is a whole-solve
    #: retrace, so this is the costliest cache to thrash.
    fused_hits: int = 0
    fused_misses: int = 0
    #: LRU evictions per cache -- the serving layer's memory-pressure signal
    #: (a multi-tenant fingerprint universe larger than the cache capacity
    #: shows up here, not as silent recompiles).  Consistency invariant for
    #: any cache whose capacity never shrank mid-run:
    #: ``evictions == misses - live_entries`` (see :func:`cache_sizes`).
    plan_evictions: int = 0
    exec_evictions: int = 0
    split_evictions: int = 0
    exchange_evictions: int = 0
    compute_evictions: int = 0
    fused_evictions: int = 0


_stats = CacheStats()
_PLAN_CACHE: "OrderedDict[tuple, StagePlan]" = OrderedDict()
_EXEC_CACHE: "OrderedDict[tuple, tuple]" = OrderedDict()
_MESH_CACHE: "OrderedDict[tuple, jax.sharding.Mesh]" = OrderedDict()
#: split-phase decompositions + jitted merge fns, keyed by pattern fingerprint
_SPLIT_CACHE: "OrderedDict[str, tuple]" = OrderedDict()
#: constructed IrregularExchange instances (per-batch dynamic-pattern callers)
_EXCHANGE_CACHE: "OrderedDict[tuple, IrregularExchange]" = OrderedDict()
#: fused whole-solve programs (jitted fn + device operands), keyed by
#: (fingerprint, solver, strategy, codec, overlap, dtype, ...) tuples built
#: by repro.solve.fused
_FUSED_CACHE: "OrderedDict[tuple, tuple]" = OrderedDict()
#: external LRUs (e.g. the SpMM compute cache) reset by clear_caches()
_EXTERNAL_CACHES: List[OrderedDict] = []
PLAN_CACHE_MAX = 256
EXEC_CACHE_MAX = 64
EXCHANGE_CACHE_MAX = 64
FUSED_CACHE_MAX = 32


def cache_stats() -> CacheStats:
    """Snapshot of plan/executor/compute cache hit counters."""
    return dataclasses.replace(_stats)


def cache_sizes() -> Dict[str, int]:
    """Live entry counts per module cache (the denominator the eviction
    counters are consistent against; see :class:`CacheStats`)."""
    return {
        "plan": len(_PLAN_CACHE),
        "exec": len(_EXEC_CACHE),
        "split": len(_SPLIT_CACHE),
        "exchange": len(_EXCHANGE_CACHE),
        "fused": len(_FUSED_CACHE),
        "external": sum(len(c) for c in _EXTERNAL_CACHES),
    }


def set_cache_limits(
    plan: Optional[int] = None,
    exec_: Optional[int] = None,
    exchange: Optional[int] = None,
    fused: Optional[int] = None,
) -> Dict[str, int]:
    """Resize the module LRU capacities, trimming oldest-first immediately.

    The serving layer's memory budget maps onto these caps: a multi-tenant
    front-end that must bound resident plan/executor state calls this with
    its budget-derived entry counts, and the trims land in the eviction
    counters like any organic pressure.  ``None`` leaves a cap unchanged;
    the split-phase cache shares ``plan``'s cap by design (one decomposition
    per resident pattern).  Returns the caps now in force.
    """
    global PLAN_CACHE_MAX, EXEC_CACHE_MAX, EXCHANGE_CACHE_MAX, FUSED_CACHE_MAX
    for name, value in (
        ("plan", plan),
        ("exec_", exec_),
        ("exchange", exchange),
        ("fused", fused),
    ):
        if value is not None and value < 1:
            raise ValueError(f"{name} cache limit must be >= 1, got {value}")
    if plan is not None:
        PLAN_CACHE_MAX = plan
        _trim(_PLAN_CACHE, plan, "plan_evictions")
        _trim(_SPLIT_CACHE, plan, "split_evictions")
    if exec_ is not None:
        EXEC_CACHE_MAX = exec_
        _trim(_EXEC_CACHE, exec_, "exec_evictions")
    if exchange is not None:
        EXCHANGE_CACHE_MAX = exchange
        _trim(_EXCHANGE_CACHE, exchange, "exchange_evictions")
    if fused is not None:
        FUSED_CACHE_MAX = fused
        _trim(_FUSED_CACHE, fused, "fused_evictions")
    return {
        "plan": PLAN_CACHE_MAX,
        "exec": EXEC_CACHE_MAX,
        "exchange": EXCHANGE_CACHE_MAX,
        "fused": FUSED_CACHE_MAX,
    }


def register_cache(cache: OrderedDict) -> None:
    """Register an external LRU so :func:`clear_caches` resets it too."""
    # identity, not equality: two distinct empty OrderedDicts compare ==
    if not any(c is cache for c in _EXTERNAL_CACHES):
        _EXTERNAL_CACHES.append(cache)


def clear_caches() -> None:
    _PLAN_CACHE.clear()
    _EXEC_CACHE.clear()
    _MESH_CACHE.clear()
    _SPLIT_CACHE.clear()
    _EXCHANGE_CACHE.clear()
    _FUSED_CACHE.clear()
    for cache in _EXTERNAL_CACHES:
        cache.clear()
    _stats.plan_hits = _stats.plan_misses = 0
    _stats.exec_hits = _stats.exec_misses = 0
    _stats.compute_hits = _stats.compute_misses = 0
    _stats.split_hits = _stats.split_misses = 0
    _stats.exchange_hits = _stats.exchange_misses = 0
    _stats.fused_hits = _stats.fused_misses = 0
    _stats.plan_evictions = _stats.exec_evictions = 0
    _stats.split_evictions = _stats.exchange_evictions = 0
    _stats.compute_evictions = _stats.fused_evictions = 0


def _trim(cache: OrderedDict, max_size: int, evict_stat: Optional[str]) -> None:
    while len(cache) > max_size:
        cache.popitem(last=False)
        if evict_stat is not None:
            setattr(_stats, evict_stat, getattr(_stats, evict_stat) + 1)


def _lru_get(
    cache: OrderedDict, key, max_size: int, build, evict_stat: Optional[str] = None
):
    if key in cache:
        cache.move_to_end(key)
        return cache[key], True
    val = build()
    cache[key] = val
    _trim(cache, max_size, evict_stat)
    return val, False


def compute_cached(cache: OrderedDict, key, max_size: int, build):
    """LRU get for a registered local-compute compile cache, with the hit /
    miss accounted under ``compute_hits`` / ``compute_misses``."""
    val, hit = _lru_get(cache, key, max_size, build, "compute_evictions")
    if hit:
        _stats.compute_hits += 1
    else:
        _stats.compute_misses += 1
    return val


def fused_cached(key, build):
    """LRU get for the fused whole-solve program cache.

    ``build()`` returns the cached value (jitted solve fn + device operands
    + exchange metadata); hits and misses land under ``fused_hits`` /
    ``fused_misses`` and trims under ``fused_evictions``, so fused programs
    participate in the same cache-pressure machinery (:func:`cache_sizes`,
    :func:`set_cache_limits`) as every other compiled artifact.
    """
    val, hit = _lru_get(_FUSED_CACHE, key, FUSED_CACHE_MAX, build, "fused_evictions")
    if hit:
        _stats.fused_hits += 1
    else:
        _stats.fused_misses += 1
    return val


def _plan_key(
    pattern: ExchangePattern,
    strategy: str,
    message_cap_bytes: int,
    elem_bytes: int,
    fuse_program: bool,
) -> tuple:
    return (
        pattern.fingerprint(),
        strategy,
        message_cap_bytes,
        elem_bytes,
        fuse_program,
    )


def planned(
    pattern: ExchangePattern,
    strategy: str,
    message_cap_bytes: int = 16384,
    elem_bytes: int = 4,
    fuse_program: bool = True,
    _key: Optional[tuple] = None,
) -> StagePlan:
    """Plan (and optionally fuse) with module-level memoization."""
    key = _key or _plan_key(
        pattern, strategy, message_cap_bytes, elem_bytes, fuse_program
    )

    def build():
        sp = plan(
            strategy,
            pattern,
            message_cap_bytes=message_cap_bytes,
            elem_bytes=elem_bytes,
        )
        return fuse(sp) if fuse_program else sp

    sp, hit = _lru_get(_PLAN_CACHE, key, PLAN_CACHE_MAX, build, "plan_evictions")
    if hit:
        _stats.plan_hits += 1
    else:
        _stats.plan_misses += 1
    return sp


def _default_mesh(topo: PodTopology) -> jax.sharding.Mesh:
    key = (topo.npods, topo.ppn)
    mesh, _ = _lru_get(_MESH_CACHE, key, 16, lambda: make_exchange_mesh(topo))
    return mesh


def _mesh_key(mesh: jax.sharding.Mesh) -> tuple:
    return (
        tuple(int(d.id) for d in mesh.devices.flat),
        tuple(mesh.devices.shape),
        tuple(mesh.axis_names),
    )


@dataclasses.dataclass(frozen=True)
class _ExecMeta:
    """Sidecar of a built executor: verify-output layout + injected delay.

    ``checks[j] = (hop ordinal, op_index, stage_kind, round_index)`` names
    the DCI hop behind column ``j`` of the executor's violation output.
    """

    emit_checks: bool
    checks: Tuple[tuple, ...]
    delay_s: float


def _executor(
    sp: StagePlan,
    plan_key: tuple,
    mesh: jax.sharding.Mesh,
    codec: str = "none",
    verify: bool = False,
    faults=None,
):
    """Build (or fetch) the jitted executor for one plan/codec/mesh.

    Returns ``(fn, arrays, meta)`` where ``meta`` is an :class:`_ExecMeta`.
    With ``verify`` on and the plan containing inter-pod hops, ``fn``
    returns ``(out, viols [nranks, n_checks])`` -- one max-violation scalar
    per DCI hop, in the program order of ``meta.checks``.  ``faults`` bakes
    a compiled :class:`repro.comm.faults.FaultPlan`'s injection masks into
    the traced program (the per-call active gating is the caller's job: it
    picks this executor or the fault-free twin per call).
    """
    fp = faults.fingerprint() if faults is not None else None
    key = plan_key + (codec, verify, fp) + _mesh_key(mesh)

    def build():
        # the barrier executor is now just shard_map over the traceable
        # program value; fused consumers embed tx.run in their own loops
        tx = traceable_exchange(sp, codec=codec, verify=verify, faults=faults)
        emit = tx.emit_checks
        specs = (P(WORLD_AXES),) * (1 + len(tx.plan_arrays))
        out_specs = (P(WORLD_AXES), P(WORLD_AXES)) if emit else P(WORLD_AXES)

        if emit:

            def run(local, *plan_arrays):
                out, viols = tx.run_verified(local, *plan_arrays)
                return out, viols[None]

        else:
            run = tx.run

        fn = jax.jit(
            shard_map(run, mesh=mesh, in_specs=specs, out_specs=out_specs)
        )
        meta = _ExecMeta(emit_checks=emit, checks=tx.checks, delay_s=tx.delay_s)
        return fn, tx.plan_arrays, meta

    val, hit = _lru_get(_EXEC_CACHE, key, EXEC_CACHE_MAX, build, "exec_evictions")
    if hit:
        _stats.exec_hits += 1
    else:
        _stats.exec_misses += 1
    return val


# ---------------------------------------------------------------------------
# Split-phase merge
# ---------------------------------------------------------------------------


def _build_merge(sp: SplitPhase):
    """Jitted per-rank gather assembling the full canonical buffer from the
    two phase outputs (no communication; sharding of axis 0 is preserved)."""
    mask = jnp.asarray(sp.from_local)
    valid = jnp.asarray(sp.valid)
    li = jnp.asarray(sp.local_idx)
    ri = jnp.asarray(sp.remote_idx)

    @jax.jit
    def merge(local_out, remote_out):
        nfeat = local_out.ndim - 2

        def take(buf, idx):
            idx = jnp.minimum(idx, buf.shape[1] - 1)
            idx = idx.reshape(idx.shape + (1,) * nfeat)
            idx = jnp.broadcast_to(idx, idx.shape[:2] + buf.shape[2:])
            return jnp.take_along_axis(buf, idx, axis=1)

        m = mask.reshape(mask.shape + (1,) * nfeat)
        v = valid.reshape(valid.shape + (1,) * nfeat)
        lo = take(local_out, li)
        merged = jnp.where(m, lo, take(remote_out, ri))
        return jnp.where(v, merged, jnp.zeros_like(lo))

    return merge


class _LazyMerge:
    """Builds the jitted split-phase merge on first call.

    Laziness matters because the jax-free consumers of the split cache
    (:class:`repro.solve.operator.NumpySpMV`) only need the decomposition;
    eagerly constructing the merge would transfer its index maps to device
    for a function they never invoke.
    """

    __slots__ = ("_sp", "_fn")

    def __init__(self, sp: SplitPhase):
        self._sp = sp
        self._fn = None

    def __call__(self, local_out, remote_out):
        if self._fn is None:
            self._fn = _build_merge(self._sp)
        return self._fn(local_out, remote_out)


def _split_phase_cached(pattern: ExchangePattern) -> tuple:
    key = pattern.fingerprint()

    def build():
        sp = split_phase(pattern)
        return sp, _LazyMerge(sp)

    val, hit = _lru_get(_SPLIT_CACHE, key, PLAN_CACHE_MAX, build, "split_evictions")
    if hit:
        _stats.split_hits += 1
    else:
        _stats.split_misses += 1
    return val


@dataclasses.dataclass
class ExchangeHandle:
    """An in-flight two-phase exchange (see :meth:`IrregularExchange.start`).

    ``local_halo`` is the on-pod phase result, available as soon as
    :meth:`IrregularExchange.start` returns; the inter-pod phase was
    dispatched first and completes asynchronously.  :meth:`finish` merges
    both phases into the full canonical recv buffer -- bit-identical to the
    barrier ``IrregularExchange.__call__``.
    """

    local_halo: jax.Array
    remote_halo: jax.Array
    _merge: object
    _done: Optional[jax.Array] = None

    def finish(self) -> jax.Array:
        """Block on the inter-pod phase and return ``[nranks, H, *feat]``."""
        if self._done is None:
            self._done = self._merge(self.local_halo, self.remote_halo)
        return self._done


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class IrregularExchange:
    """A planned, compiled irregular exchange for one strategy.

    Args:
      pattern: the element-level communication pattern.
      strategy: "standard" | "two_step" | "three_step" | "split".
      mesh: optional pre-built ``("pod", "local")`` mesh.
      message_cap_bytes: Split's user cap (Algorithm 1 input).
      elem_bytes: element width used for cap arithmetic / byte accounting.
      fuse_program: run the :mod:`repro.comm.fusion` rewrites (default on).
      wire: inter-pod wire codec, one of
        :data:`repro.comm.wire.WIRE_CODECS` (``"none"`` | ``"bf16"`` |
        ``"f16"`` | ``"int8"``).  Lossy codecs shrink only the DCI-crossing
        bytes -- on-pod hops and the destination's own-pod ``A2APod``
        blocks stay full precision -- with the per-element error bounds of
        :data:`repro.comm.wire.REL_ERROR_BOUND`; ``"none"`` is bitwise
        identical to the codec-free executor.  The plan is codec-independent
        (one plan per fingerprint); the jitted executor is cached per
        ``(plan, wire, mesh)``.

    Construction is cheap when an equal exchange was built before: the plan
    and the jitted executor come from module-level caches (see
    :func:`cache_stats`).

    Example (needs ``jax.device_count() >= pattern.topo.nranks``, e.g. via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``)::

        import numpy as np
        from repro.comm import IrregularExchange, PodTopology, random_pattern

        topo = PodTopology(npods=2, ppn=4)
        pat = random_pattern(np.random.default_rng(0), topo, local_size=6)
        ex = IrregularExchange(pat, "two_step")

        local = np.ones((topo.nranks, 6), np.float32)
        halo = ex(local)                    # barrier: [nranks, H]

        handle = ex.start(local)            # split-phase (overlap) variant:
        fast = handle.local_halo            # on-pod data, ready immediately
        assert np.array_equal(np.asarray(handle.finish()), np.asarray(halo))
    """

    pattern: ExchangePattern
    strategy: str
    mesh: Optional[jax.sharding.Mesh] = None
    message_cap_bytes: int = 16384
    elem_bytes: int = 4
    fuse_program: bool = True
    wire: str = "none"
    #: opt-in wire integrity verification (repro.comm.faults check values);
    #: a failed check raises ExchangeIntegrityError and engages the
    #: retry -> codec-demotion -> strategy-re-advise recovery ladder
    verify: bool = False
    #: seeded deterministic fault injection (repro.comm.faults.FaultPlan)
    faults: Optional[faults_mod.FaultPlan] = None
    #: shared health tracker for the ladder / advisor / watchdog; created
    #: on demand when verify or faults are set
    health: Optional[faults_mod.HealthTracker] = None
    max_retries: int = 1
    fallback: bool = True

    def __post_init__(self) -> None:
        wire_mod.check_codec(self.wire)
        plan_key = _plan_key(
            self.pattern,
            self.strategy,
            self.message_cap_bytes,
            self.elem_bytes,
            self.fuse_program,
        )
        self.plan: StagePlan = planned(
            self.pattern,
            self.strategy,
            message_cap_bytes=self.message_cap_bytes,
            elem_bytes=self.elem_bytes,
            fuse_program=self.fuse_program,
            _key=plan_key,
        )
        if self.mesh is None:
            self.mesh = _default_mesh(self.pattern.topo)
        self._fn, self._arrays, self._meta = _executor(
            self.plan, plan_key, self.mesh, self.wire, verify=self.verify
        )
        if self.faults is not None:
            self._fn_faulty, _, self._meta_faulty = _executor(
                self.plan, plan_key, self.mesh, self.wire,
                verify=self.verify, faults=self.faults,
            )
        else:
            self._fn_faulty, self._meta_faulty = self._fn, self._meta
        if self.health is None and (self.verify or self.faults is not None):
            self.health = faults_mod.HealthTracker()
        self._two_phase: Optional[tuple] = None
        self._variants: Dict[tuple, "IrregularExchange"] = {}
        self._calls = 0
        self._traceable: Optional[TraceableExchange] = None
        #: RecoveryPath.key of the most recent recovered call, or None
        self.last_recovery: Optional[str] = None

    # ------------------------------------------------------------------
    def traceable(self) -> TraceableExchange:
        """This exchange as a traceable program value (built lazily, once).

        The returned :class:`TraceableExchange` carries the same plan,
        codec, verify and fault configuration as this instance, but as a
        pure per-shard callable + plan-array pytree that callers can close
        over inside their own jitted programs (the fused solver path).
        """
        if self._traceable is None:
            self._traceable = traceable_exchange(
                self.plan, codec=self.wire, verify=self.verify,
                faults=self.faults,
            )
        return self._traceable

    # ------------------------------------------------------------------
    def __call__(self, local: jax.Array) -> jax.Array:
        """``local [nranks, L, *feat] -> canonical recv [nranks, H, *feat]``.

        Trailing feature dims (multi-vector SpMM ``k``, per-token features)
        ride along under the same plan; jit specializes per trailing shape.

        With ``verify`` or ``faults`` configured, calls run through the
        recovery ladder (:func:`repro.comm.faults.run_ladder`): a failed
        integrity check is retried up to ``max_retries`` times, then the
        lossy codec is demoted to ``"none"``, then the strategy is
        re-advised with the offending hop marked degraded; the final
        failure re-raises :class:`repro.comm.faults.ExchangeIntegrityError`.
        The fault-free default path is the unchanged direct dispatch.
        """
        n, L = self.pattern.topo.nranks, self.pattern.local_size
        if local.ndim < 2 or local.shape[:2] != (n, L):
            raise ValueError(
                f"expected [{n}, {L}, *feat], got {tuple(local.shape)}"
            )
        if self.faults is None and not self.verify:
            return self._fn(local, *self._arrays)
        return self._guarded_call(local)

    # -- verification + recovery ---------------------------------------
    def _raw_call(self, local: jax.Array, call_index: int) -> jax.Array:
        """One physical attempt: pick the faulted or clean executor by the
        FaultPlan's call gating, surface check violations as errors."""
        active = self.faults is not None and self.faults.active(call_index)
        fn, meta = (
            (self._fn_faulty, self._meta_faulty) if active else (self._fn, self._meta)
        )
        out = fn(local, *self._arrays)
        if active and meta.delay_s > 0.0:
            time.sleep(meta.delay_s)  # the injected slow-hop latency
        if meta.emit_checks:
            out, viols = out
            self._raise_from_viols(np.asarray(viols), meta.checks)
        return out

    def _raise_from_viols(self, viols: np.ndarray, checks) -> None:
        bad = (viols > 0.0).any(axis=0)
        if not bad.any():
            return
        j = int(np.argmax(bad))
        _, op_index, stage_kind, round_index = checks[j]
        raise faults_mod.ExchangeIntegrityError(
            strategy=self.plan.strategy,
            codec=self.wire,
            stage_kind=stage_kind,
            op_index=op_index,
            round_index=round_index,
            violation=float(viols[:, j].max()),
        )

    def _variant(self, strategy: str, wire: str) -> "IrregularExchange":
        if strategy == self.strategy and wire == self.wire:
            return self
        key = (strategy, wire)
        v = self._variants.get(key)
        if v is None:
            v = IrregularExchange(
                self.pattern,
                strategy,
                mesh=self.mesh,
                message_cap_bytes=self.message_cap_bytes,
                elem_bytes=self.elem_bytes,
                fuse_program=self.fuse_program,
                wire=wire,
                verify=self.verify,
                faults=self.faults,
                health=self.health,
                max_retries=0,
                fallback=False,
            )
            self._variants[key] = v
        return v

    def _guarded_call(self, local: jax.Array) -> jax.Array:
        def attempt(strategy: str, wire: str):
            idx = self._calls
            self._calls += 1
            return self._variant(strategy, wire)._raw_call(local, idx)

        out, path = faults_mod.run_ladder(
            attempt,
            strategy=self.strategy,
            wire=self.wire,
            health=self.health,
            max_retries=self.max_retries,
            fallback=self.fallback,
            choose_alternative=faults_mod.advise_alternative(
                self.pattern, self.elem_bytes
            ),
        )
        if path is not None:
            self.last_recovery = path.key
        return out

    # ------------------------------------------------------------------
    def start(self, local: jax.Array) -> ExchangeHandle:
        """Begin a split-phase exchange; on-pod data is ready immediately.

        The pattern is factored (:func:`repro.comm.exchange.split_phase`)
        into an inter-pod sub-pattern -- planned with this exchange's
        strategy and dispatched *first*, so it is in flight while anything
        else runs -- and an on-pod sub-pattern delivered synchronously as
        ``handle.local_halo``.  Work that needs no halo data (the diag-block
        product of :class:`repro.sparse.spmv.DistributedSpMV`), or only the
        on-pod part of it (``handle.local_halo``), can execute between
        ``start()`` and ``handle.finish()``, hiding the inter-node latency
        behind it; ``finish()`` merges both phases into exactly the buffer
        :meth:`__call__` returns.

        Both sub-exchanges and the merge come from the module-level caches
        (and are memoized on the instance), so repeated ``start()`` calls
        replan nothing and re-hash nothing.
        """
        if self._two_phase is None:
            sp, merge = _split_phase_cached(self.pattern)
            self._two_phase = (
                # the inter-pod phase inherits this exchange's wire codec;
                # the on-pod phase is always full precision
                IrregularExchange(
                    sp.remote,
                    self.strategy,
                    mesh=self.mesh,
                    message_cap_bytes=self.message_cap_bytes,
                    elem_bytes=self.elem_bytes,
                    fuse_program=self.fuse_program,
                    wire=self.wire,
                    # faults only ever hit DCI-crossing segments, so the
                    # guard rails ride on the inter-pod phase alone
                    verify=self.verify,
                    faults=self.faults,
                    health=self.health,
                    max_retries=self.max_retries,
                    fallback=self.fallback,
                ),
                IrregularExchange(
                    sp.local,
                    "local",
                    mesh=self.mesh,
                    elem_bytes=self.elem_bytes,
                    fuse_program=self.fuse_program,
                ),
                merge,
            )
        remote_ex, local_ex, merge = self._two_phase
        remote = remote_ex(local)  # async dispatch: inter-pod phase in flight
        return ExchangeHandle(
            local_halo=local_ex(local), remote_halo=remote, _merge=merge
        )

    # ------------------------------------------------------------------
    def reference(self, local: np.ndarray) -> np.ndarray:
        return self.pattern.reference(local)

    @property
    def wire_bytes(self) -> Tuple[int, int]:
        """(intra-pod, inter-pod) bytes on the wire incl. padding.

        Inter-pod bytes are costed at the wire codec's element width (plus
        int8 scale side information); ``wire="none"`` reports the planner's
        accounting verbatim (:func:`repro.comm.wire.scaled_wire_bytes`).
        """
        return wire_mod.scaled_wire_bytes(self.plan, self.wire, self.elem_bytes)

    @property
    def payload_bytes(self) -> Tuple[int, int]:
        """(intra-pod, inter-pod) useful payload bytes."""
        return (self.plan.intra_pod_bytes, self.plan.inter_pod_bytes)


STRATEGY_NAMES = ("standard", "two_step", "three_step", "split")


def exchange_for(
    pattern: ExchangePattern,
    strategy: str,
    *,
    mesh: Optional[jax.sharding.Mesh] = None,
    message_cap_bytes: int = 16384,
    elem_bytes: int = 4,
    wire: str = "none",
) -> IrregularExchange:
    """Memoized :class:`IrregularExchange` constructor for dynamic callers.

    Per-batch pattern producers (MoE routing) re-request an exchange every
    step; constructing a fresh instance each time is cheap-ish (plan and
    executor are already cached) but still re-runs ``__post_init__``
    bookkeeping.  This front-door LRU returns the *same* instance for an
    equal ``(fingerprint, strategy, caps, wire, mesh)`` request, so hot
    routing buckets cost one dict lookup.  Cleared by :func:`clear_caches`.
    """
    key = (
        pattern.fingerprint(),
        strategy,
        message_cap_bytes,
        elem_bytes,
        wire,
        _mesh_key(mesh) if mesh is not None else None,
    )

    def build():
        return IrregularExchange(
            pattern,
            strategy,
            mesh=mesh,
            message_cap_bytes=message_cap_bytes,
            elem_bytes=elem_bytes,
            wire=wire,
        )

    ex, hit = _lru_get(_EXCHANGE_CACHE, key, EXCHANGE_CACHE_MAX, build, "exchange_evictions")
    if hit:
        _stats.exchange_hits += 1
    else:
        _stats.exchange_misses += 1
    return ex
