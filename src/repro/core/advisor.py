"""Model-driven communication strategy selection (paper §4.6 as a feature).

Given an irregular :class:`~repro.core.patterns.CommPattern` (or raw Table 7
stats) and a machine registry entry, the advisor evaluates every Table 6
composite model and returns the ranked strategies.  This turns the paper's
characterization into the runtime decision procedure used by the SpMV driver
(``--strategy auto``) and the MoE dispatch layer.

When a :class:`ComputeProfile` is supplied, every (strategy, transport) pair
is additionally ranked in its *overlapped* (split-phase) variant, where
interior compute hides the inter-node phase
(:func:`repro.core.perfmodel.predict_overlapped`); recommendations carry an
``overlap`` flag and overlapped keys read e.g. ``"split_dd/staged_host+overlap"``.

Example (doctest)::

    >>> from repro.core import advise, figure43_pattern
    >>> pat = figure43_pattern(2048, 256, 16)
    >>> advise(pat, machine="lassen").best.key
    'two_step/device_aware'
    >>> advise(pat, machine="lassen", payload_width=16).best.key
    'three_step/device_aware'
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

from repro.core.hardware import MachineParams, get_machine
from repro.core.patterns import CommPattern
from repro.core.perfmodel import (
    WIRE_MODELS,
    LaunchModel,
    PatternStats,
    Strategy,
    Transport,
    dispatch_stats,
    get_wire,
    modeled_pairs,
    predict,
    predict_overlapped,
    predict_solver,
)


#: model-enum -> executable strategy name (repro.comm.strategies); the
#: mapping the fault ladder uses to translate advisor rankings into
#: runnable exchanges when re-advising around a degraded hop
EXECUTABLE_STRATEGY = {
    Strategy.STANDARD: "standard",
    Strategy.TWO_STEP: "two_step",
    Strategy.TWO_STEP_ONE: "two_step",
    Strategy.THREE_STEP: "three_step",
    Strategy.SPLIT_MD: "split",
    Strategy.SPLIT_DD: "split",
}


@dataclasses.dataclass(frozen=True)
class ComputeProfile:
    """Per-step local compute, split by halo dependence (seconds).

    ``t_interior`` is the compute that needs no halo data (overlappable with
    the inter-node phase); ``t_boundary`` is the halo-dependent remainder.
    Build one from a measured whole-step compute time and the row split's
    interior tile fraction via :meth:`from_fraction`.
    """

    t_interior: float
    t_boundary: float

    @property
    def total(self) -> float:
        return self.t_interior + self.t_boundary

    @staticmethod
    def from_fraction(t_compute: float, interior_fraction: float) -> "ComputeProfile":
        """Split a total compute time by the overlappable fraction.

        >>> ComputeProfile.from_fraction(1.0, 0.75)
        ComputeProfile(t_interior=0.75, t_boundary=0.25)
        """
        if not 0.0 <= interior_fraction <= 1.0:
            raise ValueError(f"interior_fraction must be in [0, 1], got {interior_fraction}")
        return ComputeProfile(
            t_interior=t_compute * interior_fraction,
            t_boundary=t_compute * (1.0 - interior_fraction),
        )


class _StrategyKey:
    """Shared ``key`` spelling for per-call and whole-solve recommendations
    (``strategy/transport`` with ``+overlap`` / ``+wire:<codec>`` suffixes)
    -- one place to keep the format the pinned regression grids assert on."""

    @property
    def key(self) -> str:
        base = f"{self.strategy.value}/{self.transport.value}"
        if self.overlap:
            base += "+overlap"
        if getattr(self, "fused", False):
            base += "+fused"
        if getattr(self, "wire", "none") != "none":
            base += f"+wire:{self.wire}"
        return base


@dataclasses.dataclass(frozen=True)
class Recommendation(_StrategyKey):
    strategy: Strategy
    transport: Transport
    predicted_time: float
    #: True when this entry models the split-phase (overlapped) execution
    overlap: bool = False
    #: inter-pod wire codec this entry models ("none" = full precision)
    wire: str = "none"


@dataclasses.dataclass(frozen=True)
class Advice:
    """Ranked strategy recommendations for one pattern on one machine."""

    machine: str
    stats: PatternStats
    ranked: Tuple[Recommendation, ...]

    @property
    def best(self) -> Recommendation:
        return self.ranked[0]

    def time_for(
        self,
        strategy: Strategy,
        transport: Transport,
        overlap: bool = False,
        wire: str = "none",
    ) -> float:
        for r in self.ranked:
            if (
                r.strategy is strategy
                and r.transport is transport
                and r.overlap == overlap
                and r.wire == wire
            ):
                return r.predicted_time
        raise KeyError((strategy, transport, overlap, wire))

    def table(self) -> str:
        w = max(len(r.key) for r in self.ranked)
        lines = [f"{'strategy':<{w}}  predicted_s"]
        lines += [f"{r.key:<{w}}  {r.predicted_time:.3e}" for r in self.ranked]
        return "\n".join(lines)


def healthy_alternatives(ranked, health, current=None):
    """Executable strategy names from a ranking, best-first, breaker-aware.

    Yields each distinct executable strategy in ranking order, skipping
    ``current`` and any strategy whose :class:`~repro.comm.faults.
    HealthTracker` breaker is OPEN.  A HALF-OPEN pair is yielded -- its
    cooldown has elapsed and it has earned exactly one probe -- which is
    how a re-advised chooser routes the probe through a healing link: if
    the probe succeeds, ``record_success`` closes the breaker, the penalty
    disappears, and subsequent :func:`advise` rankings recover the pair's
    clean position.  With ``health=None`` every strategy passes.
    """
    seen = set()
    for rec in ranked:
        name = EXECUTABLE_STRATEGY[rec.strategy]
        if name == current or name in seen:
            continue
        seen.add(name)
        if health is not None and health.is_degraded(name):
            state_of = getattr(health, "breaker_state", None)
            if state_of is None or state_of(name, rec.wire) != "half_open":
                continue
        yield name


def _wire_codecs(wire) -> Tuple[str, ...]:
    """Normalize the ``wire`` argument of :func:`advise` to codec names.

    ``None`` keeps the paper's full-precision ranking; ``"auto"`` ranks
    every executable codec; a single name or a sequence restricts the
    candidates (``"none"`` is a valid explicit candidate).
    """
    if wire is None:
        return ("none",)
    if isinstance(wire, str):
        codecs = tuple(WIRE_MODELS) if wire == "auto" else (wire,)
    else:
        codecs = tuple(wire)
    if not codecs:
        raise ValueError(
            "wire= must name at least one codec (or None / 'auto'); "
            "an empty sequence would produce an empty ranking"
        )
    for c in codecs:
        get_wire(c)  # raises ValueError on unknown names
    return codecs


def advise_stats(
    stats: PatternStats,
    machine: MachineParams | str = "tpu_v5e_pod",
    include_two_step_one: bool = False,
    duplicate_fraction: float = 0.0,
    exclude: Sequence[Tuple[Strategy, Transport]] = (),
    payload_width: int = 1,
    compute: Optional[ComputeProfile] = None,
    wire: "str | Sequence[str] | None" = None,
    health=None,
) -> Advice:
    """Rank strategies for raw Table 7 stats.

    ``duplicate_fraction`` models §4.6's duplicate-data removal: node-aware
    strategies eliminate that fraction of the standard data volume, standard
    communication does not.

    ``payload_width`` is the batched payload column count ``k`` (multi-vector
    SpMM): byte terms scale by ``k`` while message counts stay fixed (see
    :meth:`~repro.core.perfmodel.PatternStats.widened`), which is what lets
    the ranking flip between message-count-bound and bandwidth-bound winners
    as ``k`` grows.

    ``compute`` switches on overlap-aware ranking: every pair is evaluated
    both as the barrier pipeline (``T_comm + T_compute``) and as the
    split-phase pipeline (:func:`~repro.core.perfmodel.predict_overlapped`),
    and the two variants compete in one ranking.  Without a compute profile
    the ranking is communication-only, as in the paper.

    ``wire`` adds inter-pod codec variants (``+wire:<codec>`` keys, see
    :func:`_wire_codecs`): each candidate codec scales the inter-node byte
    terms by its compression ratio and pays the
    :func:`~repro.core.perfmodel.t_codec` encode+decode term, so
    bandwidth-bound patterns flip to a compressed wire while latency-bound
    patterns keep ``none``.

    ``health`` (a :class:`repro.comm.faults.HealthTracker`, or anything with
    its ``penalty(strategy, wire)`` contract) multiplies each prediction by
    the tracker's degradation penalty for the executable (strategy, codec)
    pair, so variants that failed integrity checks sink in the ranking while
    a ``None`` tracker leaves the paper's rankings untouched.  The penalty
    is not permanent: once the tracker's circuit breaker half-opens and a
    probe succeeds (``record_success``), the pair's failure count clears and
    the next ``advise`` call restores its clean position -- rankings recover
    when a link heals (see :func:`healthy_alternatives`).
    """
    m = get_machine(machine) if isinstance(machine, str) else machine
    stats = stats.widened(payload_width)
    keep = 1.0 - duplicate_fraction
    codecs = _wire_codecs(wire)
    preds = {}
    for strategy, transport in modeled_pairs(include_two_step_one):
        if (strategy, transport) in exclude:
            continue
        stats_eff = stats
        if duplicate_fraction > 0.0 and strategy is not Strategy.STANDARD:
            stats_eff = stats.scaled(keep)
        for codec in codecs:
            wm = get_wire(codec)
            pen = 1.0
            if health is not None:
                pen = health.penalty(EXECUTABLE_STRATEGY[strategy], codec)
            # the penalty orders the ranking but is not wall time, so each
            # entry carries (sort key, physical prediction): a degraded
            # pair sinks without its Recommendation.predicted_time -- what
            # schedulers charge as service time -- leaving the model
            t = predict(m, strategy, transport, stats_eff, wire=wm)
            if compute is None:
                preds[(strategy, transport, False, codec)] = (pen * t, t)
            else:
                preds[(strategy, transport, False, codec)] = (
                    pen * t + compute.total, t + compute.total
                )
                t_ov = predict_overlapped(
                    m, strategy, transport, stats_eff,
                    compute.t_interior, compute.t_boundary, wire=wm,
                )
                preds[(strategy, transport, True, codec)] = (pen * t_ov, t_ov)
    ranked = tuple(
        Recommendation(s, tr, t, overlap=ov, wire=cd)
        for (s, tr, ov, cd), (_, t) in sorted(
            preds.items(), key=lambda kv: kv[1][0]
        )
    )
    return Advice(machine=m.name, stats=stats, ranked=ranked)


def advise_routing(
    counts,
    ppn: int,
    elem_bytes: int = 4,
    payload_width: int = 1,
    machine: MachineParams | str = "tpu_v5e_pod",
    wire: "str | Sequence[str] | None" = None,
    health=None,
    include_two_step_one: bool = False,
) -> Advice:
    """Rank strategies for a measured routing histogram.

    ``counts[s, d]`` is the measured number of routed elements (MoE tokens)
    sent from rank ``s`` to rank ``d`` -- the expert-load histogram the
    router produced, not an assumed-uniform all-to-all.  ``payload_width``
    is the per-element feature width (``d_model`` for token dispatch): byte
    terms scale by it while message counts stay fixed, exactly the batched
    payload lever of :meth:`~repro.core.perfmodel.PatternStats.widened`.

    >>> import numpy as np
    >>> from repro.core import advise_routing
    >>> counts = np.full((8, 8), 64) - 64 * np.eye(8, dtype=int)
    >>> adv = advise_routing(counts, ppn=4, payload_width=32, machine="lassen")
    >>> adv.best.predicted_time < adv.ranked[-1].predicted_time
    True
    """
    return advise_stats(
        dispatch_stats(counts, ppn, elem_bytes=elem_bytes),
        machine=machine,
        payload_width=payload_width,
        wire=wire,
        health=health,
        include_two_step_one=include_two_step_one,
    )


# ---------------------------------------------------------------------------
# Iteration-amortized selection (solver workloads)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SolverRecommendation(_StrategyKey):
    """One (strategy, transport, overlap, fused) variant of a whole solve."""

    strategy: Strategy
    transport: Transport
    overlap: bool
    setup_time: float
    iter_time: float
    total_time: float
    #: True when this entry models the fused whole-solve ``lax.while_loop``
    #: front-end (one trace+launch up front, zero per-iteration dispatches);
    #: False covers both the host-driven loop (with per-dispatch launch
    #: overhead when ``fused=`` ranking is on) and the legacy launch-free
    #: accounting (``advise_solver(fused=None)``).
    fused: bool = False


@dataclasses.dataclass(frozen=True)
class SolverAdvice:
    """Ranked whole-solve recommendations for one pattern on one machine."""

    machine: str
    stats: PatternStats
    iters: int
    ranked: Tuple[SolverRecommendation, ...]

    @property
    def best(self) -> SolverRecommendation:
        return self.ranked[0]

    def time_for(
        self,
        strategy: Strategy,
        transport: Transport,
        overlap: bool = False,
        fused: bool = False,
    ) -> float:
        for r in self.ranked:
            if (
                r.strategy is strategy
                and r.transport is transport
                and r.overlap == overlap
                and r.fused == fused
            ):
                return r.total_time
        raise KeyError((strategy, transport, overlap, fused))

    def table(self) -> str:
        w = max(len(r.key) for r in self.ranked)
        lines = [f"{'strategy':<{w}}  setup_s    per_iter_s  total_s"]
        lines += [
            f"{r.key:<{w}}  {r.setup_time:.3e}  {r.iter_time:.3e}  {r.total_time:.3e}"
            for r in self.ranked
        ]
        return "\n".join(lines)


def advise_solver(
    stats: PatternStats | CommPattern,
    iters: int,
    machine: MachineParams | str = "tpu_v5e_pod",
    reductions_per_iter: float = 2.0,
    payload_width: int = 1,
    compute: Optional[ComputeProfile] = None,
    include_two_step_one: bool = False,
    exclude: Sequence[Tuple[Strategy, Transport]] = (),
    fused: "bool | str | None" = None,
    launch: Optional[LaunchModel] = None,
    matvecs_per_iter: float = 1.0,
) -> SolverAdvice:
    """Rank strategies for a whole ``iters``-iteration Krylov solve.

    The per-call ranking of :func:`advise` answers "which strategy moves one
    halo fastest"; a solver re-runs the SAME exchange ``iters`` times, so the
    question becomes amortized (paper §4.6 closing discussion):

        ``T_total = T_setup + iters * (T_step + reductions_per_iter * T_red)``

    * ``T_setup`` -- :func:`~repro.core.perfmodel.predict_setup`, paid once:
      node-aware communicator construction is several metadata rounds while
      standard communication starts almost free, so at small ``iters`` the
      standard strategy wins patterns it loses per-call;
    * ``T_step`` -- the Table 6 composite on payload-widened stats, plus the
      compute profile; with ``compute`` supplied every pair also competes as
      its split-phase ``+overlap`` variant
      (:func:`~repro.core.perfmodel.predict_overlapped`);
    * ``T_red`` -- :func:`~repro.core.perfmodel.predict_reduction`, the
      node-aware hierarchical scalar all-reduce each dot product costs
      (``reductions_per_iter``: 2 for CG, 6 for BiCGStab --
      :data:`repro.solve.krylov.REDUCTIONS_PER_ITER`).

    ``fused`` brings the execution front-end into the ranking via
    :class:`~repro.core.perfmodel.LaunchModel` (``launch``, default
    constants): ``None`` keeps the legacy launch-overhead-free accounting
    byte-identical; ``False`` / ``True`` model the host-driven loop
    (``t_launch`` per dispatch,
    :func:`~repro.core.perfmodel.launches_per_iter` dispatches per
    iteration) / the fused whole-solve ``lax.while_loop``
    (:mod:`repro.solve.fused`: one ``t_trace + t_launch`` up front, zero
    per-iteration dispatches); ``"auto"`` ranks both so short solves keep
    the host loop and long solves flip to ``+fused`` once the trace cost
    amortizes.  ``matvecs_per_iter`` follows
    :data:`repro.solve.krylov.MATVECS_PER_ITER` (1 for CG, 2 for BiCGStab).

    Doctest (the amortization flip this function exists for)::

        >>> from repro.core import advise_solver, figure43_pattern
        >>> pat = figure43_pattern(2048, 256, 16)
        >>> advise_solver(pat, iters=1, machine="lassen").best.key
        'standard/staged_host'
        >>> advise_solver(pat, iters=500, machine="lassen").best.key
        'two_step/device_aware'
    """
    if isinstance(stats, CommPattern):
        stats = stats.stats()
    if iters < 1:
        raise ValueError(f"iters must be >= 1, got {iters}")
    if fused is None:
        fused_variants: Tuple[Optional[bool], ...] = (None,)
    elif fused == "auto":
        fused_variants = (False, True)
    elif isinstance(fused, bool):
        fused_variants = (fused,)
    else:
        raise ValueError(
            f"fused= must be None, True, False or 'auto', got {fused!r}"
        )
    m = get_machine(machine) if isinstance(machine, str) else machine
    wide = stats.widened(payload_width)
    recs = []
    for strategy, transport in modeled_pairs(include_two_step_one):
        if (strategy, transport) in exclude:
            continue
        variants = [(False, 0.0, 0.0)]
        if compute is not None:
            variants = [
                (False, compute.t_interior, compute.t_boundary),
                (True, compute.t_interior, compute.t_boundary),
            ]
        for overlap, t_int, t_bnd in variants:
            for fv in fused_variants:
                setup, per_iter, total = predict_solver(
                    m,
                    strategy,
                    transport,
                    wide,
                    iters,
                    reductions_per_iter=reductions_per_iter,
                    t_interior=t_int,
                    t_boundary=t_bnd,
                    overlap=overlap,
                    setup_stats=stats,
                    fused=fv,
                    launch=launch,
                    matvecs_per_iter=matvecs_per_iter,
                )
                recs.append(
                    SolverRecommendation(
                        strategy=strategy,
                        transport=transport,
                        overlap=overlap,
                        setup_time=setup,
                        iter_time=per_iter,
                        total_time=total,
                        fused=bool(fv),
                    )
                )
    ranked = tuple(sorted(recs, key=lambda r: r.total_time))
    return SolverAdvice(machine=m.name, stats=wide, iters=iters, ranked=ranked)


def advise(
    pattern: CommPattern,
    machine: MachineParams | str = "tpu_v5e_pod",
    include_two_step_one: bool = False,
    duplicate_fraction: float = 0.0,
    payload_width: int = 1,
    compute: Optional[ComputeProfile] = None,
    wire: "str | Sequence[str] | None" = None,
    health=None,
) -> Advice:
    """Rank strategies for a concrete communication pattern.

    ``payload_width`` is the batched-payload column count ``k``,
    ``compute`` enables overlap-aware ranking, ``wire`` adds inter-pod
    codec variants with ``+wire:<codec>`` keys, and ``health`` sinks
    degraded (strategy, codec) pairs in the ranking (see
    :func:`advise_stats`).

    >>> from repro.core import figure43_pattern
    >>> adv = advise(figure43_pattern(2048, 256, 16), machine="lassen")
    >>> adv.best.key
    'two_step/device_aware'
    >>> adv.best.predicted_time < adv.ranked[-1].predicted_time
    True
    """
    return advise_stats(
        pattern.stats(),
        machine=machine,
        include_two_step_one=include_two_step_one,
        duplicate_fraction=duplicate_fraction,
        payload_width=payload_width,
        compute=compute,
        wire=wire,
        health=health,
    )
