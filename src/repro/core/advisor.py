"""Model-driven communication strategy selection (paper §4.6 as a feature).

Given an irregular :class:`~repro.core.patterns.CommPattern` (or raw Table 7
stats) and a machine registry entry, the advisor evaluates every Table 6
composite model and returns the ranked strategies.  This turns the paper's
characterization into the runtime decision procedure used by the SpMV driver
(``--strategy auto``) and the MoE dispatch layer.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

from repro.core.hardware import MachineParams, get_machine
from repro.core.patterns import CommPattern
from repro.core.perfmodel import (
    PatternStats,
    Strategy,
    Transport,
    predict_all,
)


@dataclasses.dataclass(frozen=True)
class Recommendation:
    strategy: Strategy
    transport: Transport
    predicted_time: float

    @property
    def key(self) -> str:
        return f"{self.strategy.value}/{self.transport.value}"


@dataclasses.dataclass(frozen=True)
class Advice:
    """Ranked strategy recommendations for one pattern on one machine."""

    machine: str
    stats: PatternStats
    ranked: Tuple[Recommendation, ...]

    @property
    def best(self) -> Recommendation:
        return self.ranked[0]

    def time_for(self, strategy: Strategy, transport: Transport) -> float:
        for r in self.ranked:
            if r.strategy is strategy and r.transport is transport:
                return r.predicted_time
        raise KeyError((strategy, transport))

    def table(self) -> str:
        w = max(len(r.key) for r in self.ranked)
        lines = [f"{'strategy':<{w}}  predicted_s"]
        lines += [f"{r.key:<{w}}  {r.predicted_time:.3e}" for r in self.ranked]
        return "\n".join(lines)


def advise_stats(
    stats: PatternStats,
    machine: MachineParams | str = "tpu_v5e_pod",
    include_two_step_one: bool = False,
    duplicate_fraction: float = 0.0,
    exclude: Sequence[Tuple[Strategy, Transport]] = (),
    payload_width: int = 1,
) -> Advice:
    """Rank strategies for raw Table 7 stats.

    ``duplicate_fraction`` models §4.6's duplicate-data removal: node-aware
    strategies eliminate that fraction of the standard data volume, standard
    communication does not.

    ``payload_width`` is the batched payload column count ``k`` (multi-vector
    SpMM): byte terms scale by ``k`` while message counts stay fixed (see
    :meth:`~repro.core.perfmodel.PatternStats.widened`), which is what lets
    the ranking flip between message-count-bound and bandwidth-bound winners
    as ``k`` grows.
    """
    m = get_machine(machine) if isinstance(machine, str) else machine
    stats = stats.widened(payload_width)
    keep = 1.0 - duplicate_fraction
    preds = {}
    for (strategy, transport), t in predict_all(
        m, stats, include_two_step_one=include_two_step_one
    ).items():
        if (strategy, transport) in exclude:
            continue
        if duplicate_fraction > 0.0 and strategy is not Strategy.STANDARD:
            t = predict_all(m, stats.scaled(keep), include_two_step_one=True)[
                (strategy, transport)
            ]
        preds[(strategy, transport)] = t
    ranked = tuple(
        Recommendation(s, tr, t)
        for (s, tr), t in sorted(preds.items(), key=lambda kv: kv[1])
    )
    return Advice(machine=m.name, stats=stats, ranked=ranked)


def advise(
    pattern: CommPattern,
    machine: MachineParams | str = "tpu_v5e_pod",
    include_two_step_one: bool = False,
    duplicate_fraction: float = 0.0,
    payload_width: int = 1,
) -> Advice:
    """Rank strategies for a concrete communication pattern.

    ``payload_width`` is the batched-payload column count ``k`` (see
    :func:`advise_stats`).
    """
    return advise_stats(
        pattern.stats(),
        machine=machine,
        include_two_step_one=include_two_step_one,
        duplicate_fraction=duplicate_fraction,
        payload_width=payload_width,
    )
