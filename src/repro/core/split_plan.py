"""Algorithm 1: setup for Split node-aware communication.

Faithful port of the paper's Algorithm 1.  Given per-rank receive lists, the
world topology (ranks -> nodes) and a user ``message_cap``, the planner:

1. splits messages by origin (on-node vs off-node)           [line 8]
2. plans the on-node exchange ("local_comm")                 [line 9]
3. groups off-node messages by origin node                   [line 10]
4. computes the Table 1 parameters                           [line 11]
5. resolves the effective ``message_cap``                    [lines 12-17]:
     - if ``max_IN_recv_size < message_cap``: conglomerate all inter-node
       receives into one message per origin node
     - elif ``total_IN_recv_vol / message_cap > PPN``: raise the cap to
       ``ceil(total_IN_recv_vol / PPN)``
     - then split inter-node receives into chunks of at most the cap
6. assigns chunks to on-node ranks: receives in descending size order
   starting at local rank 0; sends in ascending order from rank PPN-1
   [line 18], keeping every process active.
7. emits the redistribution plans ("local_Rcomm", "local_Scomm") and the
   inter-node exchange plan ("global_comm")                  [lines 19-21]

The output is a static :class:`SplitPlan` -- the JAX analogue of the four MPI
sub-communicators -- consumed by :mod:`repro.comm.strategies` and by the
performance models.
"""

from __future__ import annotations

import dataclasses
import math
from collections import defaultdict
from typing import Dict, List, Tuple

import numpy as np

from repro.core.patterns import CommPattern, Message


@dataclasses.dataclass(frozen=True)
class Chunk:
    """One inter-node chunk after conglomeration/splitting.

    ``origin_node -> dest_node`` carrying ``nbytes``; ``senders`` /
    ``receiver`` are the global ranks assigned by line 18; ``parts`` lists
    the (original message, byte range) pairs packed into this chunk so the
    redistribution plans can route every byte to its true destination.
    """

    origin_node: int
    dest_node: int
    nbytes: int
    sender: int
    receiver: int
    parts: Tuple[Tuple[Message, int, int], ...]  # (orig msg, offset, length)


@dataclasses.dataclass(frozen=True)
class SplitPlan:
    """Static result of Algorithm 1 for one node's receives (all nodes)."""

    pattern: CommPattern
    message_cap: int                      # user-provided cap
    effective_cap: Dict[int, int]         # per receiving node (lines 12-17)
    local_messages: Tuple[Message, ...]   # on-node origin (local_comm)
    chunks: Tuple[Chunk, ...]             # inter-node exchange (global_comm)

    # Derived plans (redistribution communicators):
    def send_redistribution(self) -> List[Tuple[int, int, int]]:
        """local_Scomm: (owner_rank -> sender_rank, nbytes) moves on the
        origin node to stage chunk bytes on their assigned senders."""
        moves = []
        for c in self.chunks:
            for msg, off, length in c.parts:
                if msg.src != c.sender:
                    moves.append((msg.src, c.sender, length))
        return moves

    def recv_redistribution(self) -> List[Tuple[int, int, int]]:
        """local_Rcomm: (receiver_rank -> final dst_rank, nbytes) moves on
        the destination node after the inter-node exchange."""
        moves = []
        for c in self.chunks:
            for msg, off, length in c.parts:
                if msg.dst != c.receiver:
                    moves.append((c.receiver, msg.dst, length))
        return moves

    # ------------------------------------------------------------------
    def total_inter_node_bytes(self) -> int:
        return sum(c.nbytes for c in self.chunks)

    def chunks_received_by(self, rank: int) -> List[Chunk]:
        return [c for c in self.chunks if c.receiver == rank]

    def chunks_sent_by(self, rank: int) -> List[Chunk]:
        return [c for c in self.chunks if c.sender == rank]


def build_split_plan(pattern: CommPattern, message_cap: int) -> SplitPlan:
    """Run Algorithm 1 over every node's receive lists."""
    if message_cap <= 0:
        raise ValueError("message_cap must be positive")
    ppn = pattern.ppn

    # Line 8: split messages by origin.
    local_msgs = tuple(
        m for m in pattern.messages if pattern.node_of(m.src) == pattern.node_of(m.dst)
    )
    inter = pattern.inter_node_messages()

    # Group inter-node messages by receiving node, then by origin node
    # (line 10).
    by_recv_node: Dict[int, Dict[int, List[Message]]] = defaultdict(lambda: defaultdict(list))
    for m in inter:
        by_recv_node[pattern.node_of(m.dst)][pattern.node_of(m.src)].append(m)

    all_chunks: List[Chunk] = []
    effective_cap: Dict[int, int] = {}

    for recv_node, by_origin in sorted(by_recv_node.items()):
        # Line 11: Table 1 parameters for this node.
        per_origin_vol = {o: sum(m.nbytes for m in msgs) for o, msgs in by_origin.items()}
        total_in_recv_vol = sum(per_origin_vol.values())
        max_in_recv_size = max(per_origin_vol.values())

        # Lines 12-17: resolve the effective cap.
        if max_in_recv_size < message_cap:
            cap = max(max_in_recv_size, 1)  # conglomerate: one msg per origin node
        elif total_in_recv_vol / message_cap > ppn:
            cap = math.ceil(total_in_recv_vol / ppn)  # line 16
        else:
            cap = message_cap
        effective_cap[recv_node] = cap

        # Conglomerate per origin node, then split to chunks of <= cap.
        raw_chunks: List[Tuple[int, int, List[Tuple[Message, int, int]]]] = []
        for origin in sorted(by_origin):
            msgs = sorted(by_origin[origin], key=lambda m: (m.dst, m.src))
            parts: List[Tuple[Message, int, int]] = []
            size = 0
            for m in msgs:
                off = 0
                while off < m.nbytes:
                    take = min(cap - size, m.nbytes - off)
                    parts.append((m, off, take))
                    size += take
                    off += take
                    if size == cap:
                        raw_chunks.append((origin, size, parts))
                        parts, size = [], 0
            if size or (not parts and not raw_chunks):
                if size:
                    raw_chunks.append((origin, size, parts))

        # Line 18: receives in descending size from local rank 0; sends in
        # ascending order from local rank PPN-1 (per origin node).
        raw_chunks.sort(key=lambda t: -t[1])
        node_base = recv_node * ppn
        send_counters: Dict[int, int] = defaultdict(int)
        for i, (origin, size, parts) in enumerate(raw_chunks):
            receiver = node_base + (i % ppn)
            k = send_counters[origin]
            sender = origin * ppn + (ppn - 1 - (k % ppn))
            send_counters[origin] += 1
            all_chunks.append(
                Chunk(
                    origin_node=origin,
                    dest_node=recv_node,
                    nbytes=size,
                    sender=sender,
                    receiver=receiver,
                    parts=tuple(parts),
                )
            )

    return SplitPlan(
        pattern=pattern,
        message_cap=message_cap,
        effective_cap=effective_cap,
        local_messages=local_msgs,
        chunks=tuple(all_chunks),
    )


# ---------------------------------------------------------------------------
# Interior / boundary row split (the overlap enabler, paper §4.6 discussion)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RowPhaseSplit:
    """Each rank's rows partitioned for split-phase (overlapped) compute.

    A row is *interior* when it depends only on entries its own rank holds
    -- its compute can run while the inter-node exchange is in flight -- and
    *boundary* when it reads halo data and must wait for
    ``ExchangeHandle.finish()``.  Row-tile granularity matters on TPU: the
    blocked-ELL kernels compute whole ``tile_rows`` tiles, so a tile is
    interior only if *every* row in it is (``interior_tiles``); any halo
    dependency promotes the whole tile to the boundary phase.

    Attributes:
      interior: ``[nranks, L]`` bool, True for halo-independent rows.
      interior_tiles: ``[nranks, ntiles]`` bool at kernel tile granularity.
      tile_rows: the row-tile size the tile masks were computed for.
    """

    interior: np.ndarray
    interior_tiles: np.ndarray
    tile_rows: int

    @property
    def boundary(self) -> np.ndarray:
        return ~self.interior

    @property
    def boundary_tiles(self) -> np.ndarray:
        return ~self.interior_tiles

    @property
    def interior_fraction(self) -> float:
        """Fraction of rows whose compute overlaps the inter-node phase
        (the x-axis of ``benchmarks/bench_overlap.py``)."""
        return float(self.interior.mean()) if self.interior.size else 0.0

    @property
    def interior_tile_fraction(self) -> float:
        """Fraction of *tiles* that overlap -- what the kernels actually
        skip; always <= ``interior_fraction``."""
        return (
            float(self.interior_tiles.mean()) if self.interior_tiles.size else 0.0
        )


def split_rows(halo_dependent: np.ndarray, tile_rows: int) -> RowPhaseSplit:
    """Partition rows into interior/boundary sets from a dependency mask.

    ``halo_dependent[r, i]`` is True when row ``i`` of rank ``r`` reads at
    least one off-rank (halo) entry -- for the SpMV case this is "row has a
    nonzero in the off-rank ELL block".  ``tile_rows`` is the kernel's
    row-tile size; rows are padded up to a whole number of tiles and padding
    rows count as interior (they compute zeros either way).
    """
    if halo_dependent.ndim != 2:
        raise ValueError(
            f"halo_dependent must be [nranks, rows], got {halo_dependent.shape}"
        )
    if tile_rows < 1:
        raise ValueError(f"tile_rows must be >= 1, got {tile_rows}")
    dep = np.asarray(halo_dependent, dtype=bool)
    nranks, L = dep.shape
    ntiles = -(-L // tile_rows) if L else 0
    pad = ntiles * tile_rows - L
    padded = np.pad(dep, ((0, 0), (0, pad)))
    tile_dep = padded.reshape(nranks, ntiles, tile_rows).any(axis=2)
    return RowPhaseSplit(
        interior=~dep, interior_tiles=~tile_dep, tile_rows=tile_rows
    )
