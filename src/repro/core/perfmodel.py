"""Performance models for node-aware irregular point-to-point communication.

Implements, faithfully, the models of paper §2.2 / §4:

* eq. (2.1)  postal model            ``T = alpha + beta * s``
* eq. (2.2)  max-rate model          ``T = alpha*m + max(ppn*s/R_N, s/R_b)``
* eq. (4.1)  T_on        -- worst-case on-node gather/redistribute (3-Step, 2-Step)
* eq. (4.2)  T_on-split  -- on-node distribute for the Split strategies
* eq. (4.3)  T_off       -- staged-through-host inter-node (max-rate form)
* eq. (4.4)  T_off-DA    -- device-aware inter-node (postal form)
* eq. (4.5)  T_copy      -- staging copies between device and host
* Table 6    composite models for all (strategy x transport) pairs

plus the Table 7 pattern statistics consumed by the composites (computed by
:mod:`repro.core.patterns`), plus the overlap-aware extension used by the
split-phase execution path: :func:`predict_phases` factors each Table 6
composite into its on-node and inter-node terms, and
:func:`predict_overlapped` evaluates

    ``T = T_local_comm + max(T_inter_comm, T_interior_compute) + T_boundary``

-- the split-phase pipeline where interior compute hides behind the
inter-node phase (paper §4.6 closing discussion; Bienz et al., "Modeling
Data Movement Performance on Heterogeneous Architectures").

Wire codecs (:mod:`repro.comm.wire`) extend every composite with a third
lever: a :class:`WireModel` scales the inter-node *byte* terms by its
compression ratio (message counts and every on-node term are untouched --
exactly the executor's behaviour, which encodes only DCI-crossing
segments) and adds an unhideable encode+decode compute term to the local
phase.  ``predict(..., wire=...)`` / ``predict_phases`` /
``predict_overlapped`` stay mutually consistent:
``predict_phases(...).total == predict(...)`` for every codec.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Dict, Optional, Tuple

from repro.core.hardware import (
    CopyParams,
    Locality,
    MachineParams,
    Space,
)


class Strategy(enum.Enum):
    """Node-aware strategies modeled by the paper (Table 5)."""

    STANDARD = "standard"
    THREE_STEP = "three_step"
    TWO_STEP = "two_step"
    TWO_STEP_ONE = "two_step_1"  # best-case 2-Step (single active GPU), Fig 4.3
    SPLIT_MD = "split_md"
    SPLIT_DD = "split_dd"


class Transport(enum.Enum):
    DEVICE_AWARE = "device_aware"
    STAGED_HOST = "staged_host"


#: (strategy, transport) pairs the paper models (Table 5). Split strategies
#: are staged-through-host only ("device-aware communication does not apply").
MODELED_PAIRS = [
    (Strategy.STANDARD, Transport.STAGED_HOST),
    (Strategy.STANDARD, Transport.DEVICE_AWARE),
    (Strategy.THREE_STEP, Transport.STAGED_HOST),
    (Strategy.THREE_STEP, Transport.DEVICE_AWARE),
    (Strategy.TWO_STEP, Transport.STAGED_HOST),
    (Strategy.TWO_STEP, Transport.DEVICE_AWARE),
    (Strategy.SPLIT_MD, Transport.STAGED_HOST),
    (Strategy.SPLIT_DD, Transport.STAGED_HOST),
]


def modeled_pairs(
    include_two_step_one: bool = False,
) -> "list[Tuple[Strategy, Transport]]":
    """The candidate (strategy, transport) pairs -- the ONE enumeration the
    advisor and :func:`predict_all` share, so the optional best-case 2-Step
    extension cannot drift between them."""
    pairs = list(MODELED_PAIRS)
    if include_two_step_one:
        pairs += [
            (Strategy.TWO_STEP_ONE, Transport.STAGED_HOST),
            (Strategy.TWO_STEP_ONE, Transport.DEVICE_AWARE),
        ]
    return pairs


@dataclasses.dataclass(frozen=True)
class PatternStats:
    """Table 7 parameters (plus ``s_node_total`` used by the Split row).

    Attributes:
      s_proc: max bytes sent by a single process/GPU.
      s_node: max bytes injected into the network by a single node.
      s_node_node: max bytes sent between any two nodes.
      m_proc_node: max number of nodes to which a single process sends.
      m_node_node: max number of messages between any two nodes.
      m_proc: max number of messages sent by a single process (standard).
      num_dest_nodes: number of destination nodes for the max-injecting node.
    """

    s_proc: float
    s_node: float
    s_node_node: float
    m_proc_node: int
    m_node_node: int
    m_proc: int
    num_dest_nodes: int

    def scaled(self, keep: float) -> "PatternStats":
        """Scale data volumes by ``keep`` (duplicate-data removal, §4.6)."""
        return dataclasses.replace(
            self,
            s_proc=self.s_proc * keep,
            s_node=self.s_node * keep,
            s_node_node=self.s_node_node * keep,
        )

    def widened(self, payload_width: int) -> "PatternStats":
        """Byte terms for a batched payload of ``payload_width`` columns.

        A batched exchange ships ``k`` feature columns per element under one
        plan (multi-vector SpMM, batched serving), so every byte volume grows
        ``k``-fold while the message counts stay fixed: the per-message
        ``alpha`` terms amortize across columns and the models slide from the
        message-count-bound regime toward the bandwidth-bound regime as ``k``
        grows (Bienz et al.; the heterogeneous-communication survey's batched
        payload lever).

        >>> s = PatternStats(s_proc=100.0, s_node=400.0, s_node_node=200.0,
        ...                  m_proc_node=4, m_node_node=8, m_proc=16,
        ...                  num_dest_nodes=4)
        >>> w = s.widened(8)
        >>> (w.s_proc, w.s_node)      # byte terms scale by k ...
        (800.0, 3200.0)
        >>> (w.m_proc, w.m_node_node) # ... message counts do not
        (16, 8)
        >>> s.widened(1) is s
        True
        """
        if payload_width < 1:
            raise ValueError(f"payload_width must be >= 1, got {payload_width}")
        if payload_width == 1:
            return self
        return self.scaled(float(payload_width))


def dispatch_stats(counts, ppn: int, elem_bytes: int = 4) -> PatternStats:
    """Table 7 stats straight from a measured ``[nranks, nranks]`` count matrix.

    ``counts[s, d]`` is the number of elements rank ``s`` sends to rank ``d``
    (an expert-load histogram for MoE token dispatch: tokens routed from data
    shard ``s`` to the shard owning the chosen expert).  This is the
    histogram-driven advisor input of the paper lineage ("Improving
    Performance Models for Irregular Point-to-Point Communication"): measured
    per-pair traffic instead of an assumed-uniform all-to-all.  The diagonal
    (self traffic) never hits the network and is ignored.

    One vectorized numpy pass; semantically identical to building a
    :class:`~repro.core.patterns.CommPattern` with one message per nonzero
    off-diagonal pair and calling ``.stats()`` (pinned by a test).
    """
    import numpy as np

    c = np.asarray(counts, dtype=np.float64)
    if c.ndim != 2 or c.shape[0] != c.shape[1]:
        raise ValueError(f"counts must be a square matrix, got {c.shape}")
    if (c < 0).any():
        raise ValueError("counts must be non-negative")
    n = c.shape[0]
    if n % ppn:
        raise ValueError(f"nranks {n} not divisible by ppn {ppn}")
    nn = n // ppn
    b = c * float(elem_bytes)
    node = np.arange(n) // ppn
    inter = node[:, None] != node[None, :]  # inter-node pair mask
    bi = np.where(inter, b, 0.0)
    mi = np.where(inter, c > 0, False)
    # per-node-pair block sums / counts: [nn, ppn, nn, ppn] -> [nn, nn]
    b4 = bi.reshape(nn, ppn, nn, ppn)
    m4 = mi.reshape(nn, ppn, nn, ppn)
    pair_bytes = b4.sum(axis=(1, 3))
    pair_msgs = m4.sum(axis=(1, 3))
    dest_nodes_by_src = (m4.any(axis=3)).astype(np.int64)  # [nn, ppn, nn]
    return PatternStats(
        s_proc=float(bi.sum(axis=1).max(initial=0.0)),
        s_node=float(pair_bytes.sum(axis=1).max(initial=0.0)),
        s_node_node=float(pair_bytes.max(initial=0.0)),
        m_proc_node=int(dest_nodes_by_src.sum(axis=2).max(initial=0)),
        m_node_node=int(pair_msgs.max(initial=0)),
        m_proc=int(mi.sum(axis=1).max(initial=0)),
        num_dest_nodes=int(dest_nodes_by_src.any(axis=1).sum(axis=1).max(initial=0)),
    )


# ---------------------------------------------------------------------------
# Wire codec models (inter-node byte compression, repro.comm.wire)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class WireModel:
    """Model parameters of one inter-pod wire codec.

    Attributes:
      codec: executable codec name (``repro.comm.wire.WIRE_CODECS``).
      ratio: inter-node byte multiplier (0.5 for 16-bit wires; the int8
        entry carries a little extra for the per-block float32 scales).
      alpha: per-exchange encode+decode launch overhead, seconds.
      beta: per-byte codec compute cost, seconds/byte, paid once for the
        encode pass and once for the decode pass over the max node
        injection volume ``s_node`` (the quantizer's extra amax sweep is
        folded into the int8 beta).

    The codec compute term is *unhideable*: encoding must finish before the
    inter-node dispatch and decoding starts after arrival, so
    :func:`predict_phases` charges it to the local phase and the split-phase
    pipeline of :func:`predict_overlapped` cannot hide it.
    """

    codec: str
    ratio: float
    alpha: float
    beta: float


#: model constants per executable codec.  Recorded at pin time next to the
#: machine registry numbers: 16-bit casts halve DCI bytes and stream the
#: payload once per side at on-device memory bandwidth (~1 TB/s); int8
#: quarters the bytes (plus ~1% for scales) but pays an extra amax sweep.
WIRE_MODELS: Dict[str, WireModel] = {
    "none": WireModel("none", 1.0, 0.0, 0.0),
    "bf16": WireModel("bf16", 0.5, 1.0e-6, 1.0e-12),
    "f16": WireModel("f16", 0.5, 1.0e-6, 1.0e-12),
    "int8": WireModel("int8", 0.26, 1.0e-6, 2.0e-12),
}


def get_wire(wire: "WireModel | str | None") -> WireModel:
    """Normalize a codec name / model / ``None`` to a :class:`WireModel`."""
    if wire is None:
        return WIRE_MODELS["none"]
    if isinstance(wire, WireModel):
        return wire
    try:
        return WIRE_MODELS[wire]
    except KeyError as e:
        # ValueError to match the executor-side validation (wire.check_codec,
        # IrregularExchange, execute_numpy): callers catch one exception type
        # for a bad user-supplied codec name
        raise ValueError(
            f"unknown wire codec {wire!r}; known: {sorted(WIRE_MODELS)}"
        ) from e


def t_codec(wire: "WireModel | str | None", s_node: float) -> float:
    """Encode+decode compute of one exchange (0 for the ``none`` codec)."""
    w = get_wire(wire)
    if w.codec == "none":
        return 0.0
    return w.alpha + 2.0 * w.beta * float(s_node)


# ---------------------------------------------------------------------------
# Primitive models
# ---------------------------------------------------------------------------


def postal(alpha: float, beta: float, nbytes: float, nmsgs: int = 1) -> float:
    """Eq. (2.1): ``T = alpha + beta*s`` (per message, ``nmsgs`` messages)."""
    return alpha * nmsgs + beta * float(nbytes)


def max_rate(
    alpha: float,
    beta: float,
    nmsgs: int,
    s_proc: float,
    s_node: float,
    rn_inv: float,
) -> float:
    """Eq. (2.2)/(4.3): ``T = alpha*m + max(s_node/R_N, s_proc*beta)``.

    ``s_node/R_N`` is the node injection-bandwidth bound; ``s_proc*beta`` is
    the per-process transport bound.  When the node is injecting less than
    the NIC limit this reduces to the postal model.
    """
    return alpha * nmsgs + max(s_node * rn_inv, s_proc * beta)


# ---------------------------------------------------------------------------
# Sub-models (paper §4.1-§4.4)
# ---------------------------------------------------------------------------


def t_on(machine: MachineParams, space: Space, s: float) -> float:
    """Eq. (4.1): worst-case on-node gather or redistribute for 3-/2-Step.

    ``(gps-1)`` on-socket messages plus ``gps`` on-node messages of size
    ``s`` (the max contribution of a single GPU).
    """
    gps = machine.gpus_per_socket
    p_sock = machine.path(space, Locality.ON_SOCKET, s)
    p_node = machine.path(space, Locality.ON_NODE, s)
    t = (gps - 1) * (p_sock.alpha + p_sock.beta * s)
    if machine.sockets_per_node > 1:
        t += gps * (p_node.alpha + p_node.beta * s)
    return t


def t_on_split(machine: MachineParams, s_total: float, ppg: int) -> float:
    """Eq. (4.2): on-node distribute/redistribute for the Split strategies.

    Worst case: a single GPU holds all ``s_total`` inter-node bytes, staged on
    ``ppg`` host processes, and must spread them over all ``PPN`` on-node
    processes in chunks of ``s_total/PPN``: each staging process sends
    ``pps/ppg - 1`` on-socket and ``pps/ppg`` off-socket/on-node messages
    (19 + 20 on Lassen with ppg=1).  Staging is always through host
    processes, so CPU path parameters apply.
    """
    pps = machine.procs_per_socket
    ppn = machine.procs_per_node
    chunk = s_total / ppn
    n_sock = pps // ppg - 1
    n_node = pps // ppg if machine.sockets_per_node > 1 else 0
    p_sock = machine.path(Space.CPU, Locality.ON_SOCKET, chunk)
    t = n_sock * (p_sock.alpha + p_sock.beta * chunk)
    if n_node:
        p_node = machine.path(Space.CPU, Locality.ON_NODE, chunk)
        t += n_node * (p_node.alpha + p_node.beta * chunk)
    return t


def t_off(
    machine: MachineParams,
    nmsgs: int,
    s_proc: float,
    s_node: float,
    msg_size: Optional[float] = None,
) -> float:
    """Eq. (4.3): staged-through-host inter-node communication (max-rate).

    ``msg_size`` selects the protocol class (defaults to ``s_proc``).
    """
    p = machine.path(Space.CPU, Locality.OFF_NODE, msg_size if msg_size is not None else s_proc)
    return max_rate(p.alpha, p.beta, nmsgs, s_proc, s_node, machine.rn_inv)


def t_off_da(machine: MachineParams, nmsgs: int, s: float, msg_size: Optional[float] = None) -> float:
    """Eq. (4.4): device-aware inter-node communication (postal)."""
    p = machine.path(Space.GPU, Locality.OFF_NODE, msg_size if msg_size is not None else s)
    return p.alpha * nmsgs + s * p.beta


def t_copy(copy: CopyParams, s_send: float, s_recv: float) -> float:
    """Eq. (4.5): device<->host staging copies."""
    return (
        copy.h2d.alpha
        + copy.h2d.beta * s_send
        + copy.d2h.alpha
        + copy.d2h.beta * s_recv
    )


# ---------------------------------------------------------------------------
# Table 6 composites
# ---------------------------------------------------------------------------


def predict(
    machine: MachineParams,
    strategy: Strategy,
    transport: Transport,
    stats: PatternStats,
    wire: "WireModel | str | None" = None,
) -> float:
    """Predicted time for one (strategy, transport) pair -- paper Table 6.

    ``wire`` selects an inter-node codec (:data:`WIRE_MODELS`): byte terms
    of the inter-node phase scale by its compression ratio and the local
    phase pays :func:`t_codec`; consistent with :func:`predict_phases` by
    construction (``predict == predict_phases(...).total``).
    """
    w = get_wire(wire)
    if w.codec != "none":
        return predict_phases(machine, strategy, transport, stats, wire=w).total
    return _predict_base(machine, strategy, transport, stats)


def _predict_base(
    machine: MachineParams,
    strategy: Strategy,
    transport: Transport,
    stats: PatternStats,
) -> float:
    ppn = machine.procs_per_node

    if strategy is Strategy.STANDARD:
        if transport is Transport.STAGED_HOST:
            # Max-rate model (2.2), staged through host: CPU off-node params.
            msg = stats.s_proc / max(stats.m_proc, 1)
            p = machine.path(Space.CPU, Locality.OFF_NODE, msg)
            return max_rate(p.alpha, p.beta, stats.m_proc, stats.s_proc, stats.s_node, machine.rn_inv)
        # Postal model (2.1), device-aware: GPU off-node params.
        msg = stats.s_proc / max(stats.m_proc, 1)
        p = machine.path(Space.GPU, Locality.OFF_NODE, msg)
        return p.alpha * stats.m_proc + p.beta * stats.s_proc

    if strategy is Strategy.THREE_STEP:
        if transport is Transport.STAGED_HOST:
            return (
                t_off(machine, stats.m_node_node, stats.s_node_node, stats.s_node,
                      msg_size=stats.s_node_node)
                + 2.0 * t_on(machine, Space.CPU, stats.s_node_node)
                + t_copy(machine.copy[1], stats.s_proc, stats.s_node_node)
            )
        return (
            t_off_da(machine, stats.m_node_node, stats.s_node_node)
            + 2.0 * t_on(machine, Space.GPU, stats.s_node_node)
        )

    if strategy in (Strategy.TWO_STEP, Strategy.TWO_STEP_ONE):
        # 2-Step All: every GPU sends to its pair on each destination node.
        # 2-Step 1 (best case): all inter-node data originates on one GPU that
        # is already paired with the destination -- on-node phase vanishes.
        if transport is Transport.STAGED_HOST:
            t = t_off(machine, stats.m_proc_node, stats.s_proc, stats.s_node,
                      msg_size=stats.s_proc / max(stats.m_proc_node, 1))
            if strategy is Strategy.TWO_STEP:
                t += t_on(machine, Space.CPU, stats.s_proc)
            return t + t_copy(machine.copy[1], stats.s_proc, stats.s_node_node)
        t = t_off_da(machine, stats.m_proc_node, stats.s_proc,
                     msg_size=stats.s_proc / max(stats.m_proc_node, 1))
        if strategy is Strategy.TWO_STEP:
            t += t_on(machine, Space.GPU, stats.s_proc)
        return t

    if strategy in (Strategy.SPLIT_MD, Strategy.SPLIT_DD):
        if transport is not Transport.STAGED_HOST:
            raise ValueError("device-aware transport does not apply to Split (paper Table 5)")
        ppg = 1 if strategy is Strategy.SPLIT_MD else 4
        s_split = stats.s_node / ppn
        return (
            t_off(machine, stats.m_proc_node, s_split, stats.s_node, msg_size=s_split)
            + 2.0 * t_on_split(machine, stats.s_node, ppg)
            + t_copy(machine.copy[ppg], stats.s_proc, stats.s_node_node)
        )

    raise ValueError(f"unknown strategy {strategy}")


# ---------------------------------------------------------------------------
# Overlap-aware extension (split-phase execution)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PhaseTimes:
    """A Table 6 composite factored into its two communication phases.

    ``local`` collects every on-node term (gathers, redistributes, staging
    copies) -- the part of the exchange that cannot be hidden because the
    split-phase pipeline needs it before interior compute starts; ``inter``
    is the inter-node transport term -- the part that runs concurrently with
    interior compute when the execution path overlaps
    (:meth:`repro.sparse.spmv.DistributedSpMV` with ``overlap=True``).
    """

    local: float
    inter: float

    @property
    def total(self) -> float:
        return self.local + self.inter


def predict_phases(
    machine: MachineParams,
    strategy: Strategy,
    transport: Transport,
    stats: PatternStats,
    wire: "WireModel | str | None" = None,
) -> PhaseTimes:
    """Factor the Table 6 composite into (on-node, inter-node) terms.

    Invariant (pinned by tests): ``phases.local + phases.inter`` equals
    :func:`predict` for every modeled pair and every wire codec.

    With a ``wire`` codec the inter phase is evaluated on ratio-scaled byte
    stats (message counts untouched -- the codec shrinks bytes, not
    messages) and the local phase pays the unhideable :func:`t_codec`
    encode+decode term.
    """
    w = get_wire(wire)
    base = _predict_phases_base(machine, strategy, transport, stats)
    if w.codec == "none":
        return base
    inter = _predict_phases_base(
        machine, strategy, transport, stats.scaled(w.ratio)
    ).inter
    return PhaseTimes(local=base.local + t_codec(w, stats.s_node), inter=inter)


def _predict_phases_base(
    machine: MachineParams,
    strategy: Strategy,
    transport: Transport,
    stats: PatternStats,
) -> PhaseTimes:
    ppn = machine.procs_per_node

    if strategy is Strategy.STANDARD:
        return PhaseTimes(
            local=0.0, inter=_predict_base(machine, strategy, transport, stats)
        )

    if strategy is Strategy.THREE_STEP:
        if transport is Transport.STAGED_HOST:
            return PhaseTimes(
                local=2.0 * t_on(machine, Space.CPU, stats.s_node_node)
                + t_copy(machine.copy[1], stats.s_proc, stats.s_node_node),
                inter=t_off(machine, stats.m_node_node, stats.s_node_node,
                            stats.s_node, msg_size=stats.s_node_node),
            )
        return PhaseTimes(
            local=2.0 * t_on(machine, Space.GPU, stats.s_node_node),
            inter=t_off_da(machine, stats.m_node_node, stats.s_node_node),
        )

    if strategy in (Strategy.TWO_STEP, Strategy.TWO_STEP_ONE):
        on_space = Space.CPU if transport is Transport.STAGED_HOST else Space.GPU
        local = (
            t_on(machine, on_space, stats.s_proc)
            if strategy is Strategy.TWO_STEP
            else 0.0
        )
        if transport is Transport.STAGED_HOST:
            local += t_copy(machine.copy[1], stats.s_proc, stats.s_node_node)
            inter = t_off(machine, stats.m_proc_node, stats.s_proc, stats.s_node,
                          msg_size=stats.s_proc / max(stats.m_proc_node, 1))
        else:
            inter = t_off_da(machine, stats.m_proc_node, stats.s_proc,
                             msg_size=stats.s_proc / max(stats.m_proc_node, 1))
        return PhaseTimes(local=local, inter=inter)

    if strategy in (Strategy.SPLIT_MD, Strategy.SPLIT_DD):
        if transport is not Transport.STAGED_HOST:
            raise ValueError("device-aware transport does not apply to Split (paper Table 5)")
        ppg = 1 if strategy is Strategy.SPLIT_MD else 4
        s_split = stats.s_node / ppn
        return PhaseTimes(
            local=2.0 * t_on_split(machine, stats.s_node, ppg)
            + t_copy(machine.copy[ppg], stats.s_proc, stats.s_node_node),
            inter=t_off(machine, stats.m_proc_node, s_split, stats.s_node,
                        msg_size=s_split),
        )

    raise ValueError(f"unknown strategy {strategy}")


def predict_overlapped(
    machine: MachineParams,
    strategy: Strategy,
    transport: Transport,
    stats: PatternStats,
    t_interior: float,
    t_boundary: float,
    wire: "WireModel | str | None" = None,
) -> float:
    """Split-phase pipeline time with interior compute hiding the inter-node
    phase: ``T = T_local + max(T_inter, T_interior) + T_boundary``.

    ``t_interior`` / ``t_boundary`` are the interior-tile and boundary-tile
    local compute times in seconds (e.g. from a measured per-step compute
    time scaled by :attr:`repro.core.split_plan.RowPhaseSplit.interior_tile_fraction`).
    The non-overlapped counterpart of the same step is
    ``predict(...) + t_interior + t_boundary``.  A ``wire`` codec shrinks
    the hideable inter phase but its :func:`t_codec` term lands in
    ``T_local`` -- compression buys less once compute already hides the
    inter-node time.
    """
    if t_interior < 0 or t_boundary < 0:
        raise ValueError("compute times must be non-negative")
    ph = predict_phases(machine, strategy, transport, stats, wire=wire)
    return ph.local + max(ph.inter, t_interior) + t_boundary


# ---------------------------------------------------------------------------
# Iteration-amortized extension (solver workloads)
# ---------------------------------------------------------------------------

#: metadata-exchange rounds paid once at communicator construction.  The
#: standard strategy posts its receive lists directly (one round); the
#: node-aware strategies additionally gather per-process destination lists
#: on-node and scatter the redistribution maps back (two more rounds --
#: the communicator-construction phase of §2.3); Split runs Algorithm 1's
#: chunk-assignment negotiation on top (one more).
SETUP_META_ROUNDS: Dict[Strategy, int] = {
    Strategy.STANDARD: 1,
    Strategy.THREE_STEP: 3,
    Strategy.TWO_STEP: 3,
    Strategy.TWO_STEP_ONE: 3,
    Strategy.SPLIT_MD: 4,
    Strategy.SPLIT_DD: 4,
}


def _log2ceil(n: int) -> int:
    return max(1, (max(int(n), 1) - 1).bit_length())


def predict_setup(
    machine: MachineParams,
    strategy: Strategy,
    transport: Transport,
    stats: PatternStats,
) -> float:
    """One-time communicator-construction cost for a (strategy, transport).

    The paper's closing discussion (and Bienz et al.'s irregular-p2p
    modeling) notes node-aware strategies only pay off once their setup --
    exchanging index metadata and building the node communicator -- is
    amortized over many identical exchanges.  Modeled as:

    * ``SETUP_META_ROUNDS[strategy]`` metadata exchanges costed at the
      strategy's own Table 6 composite (index lists are 4-byte tokens, the
      same volume as one ``k=1`` payload), plus
    * for node-aware strategies, one on-node gather + scatter of the
      per-process maps (eq. 4.1) and a per-node-pair count agreement over a
      log-depth inter-node tree.

    Call with **unwidened** stats: metadata volume does not scale with the
    batched payload width ``k``.
    """
    t = SETUP_META_ROUNDS[strategy] * predict(machine, strategy, transport, stats)
    if strategy is not Strategy.STANDARD:
        space = Space.GPU if transport is Transport.DEVICE_AWARE else Space.CPU
        t += 2.0 * t_on(machine, space, stats.s_proc)
        p = machine.path(Space.CPU, Locality.OFF_NODE, 8.0)
        t += 2.0 * _log2ceil(stats.num_dest_nodes) * p.alpha
    return t


def predict_reduction(
    machine: MachineParams,
    stats: PatternStats,
    nbytes: float = 8.0,
) -> float:
    """Latency of one node-aware hierarchical scalar all-reduce.

    The solver's dot products follow the same hierarchy as the exchange
    strategies (``repro.comm.hierarchical.dot_hierarchical``): a log-depth
    on-node tree over the PPN processes, then a log-depth inter-node tree
    over the destination-node set, then the on-node broadcast back.  The
    payload is ``nbytes`` (one float64 scalar by default), so every term is
    latency-bound.  Strategy-independent: it shifts all solver totals
    equally but keeps per-iteration predictions honest.
    """
    p_on = machine.path(Space.CPU, Locality.ON_SOCKET, nbytes)
    p_off = machine.path(Space.CPU, Locality.OFF_NODE, nbytes)
    on = 2.0 * _log2ceil(machine.procs_per_node) * (p_on.alpha + p_on.beta * nbytes)
    off = _log2ceil(stats.num_dest_nodes) * (p_off.alpha + p_off.beta * nbytes)
    return on + off


@dataclasses.dataclass(frozen=True)
class LaunchModel:
    """Host-side dispatch overheads of an iterative solve.

    The host-driven Krylov loop (:mod:`repro.solve.krylov`) re-enters the
    runtime several times per iteration -- one jitted dispatch per exchange
    phase, matvec kernel, and scalar reduction -- and each re-entry costs a
    fixed host round-trip ``t_launch`` regardless of payload (the classic
    argument for triggered operations / on-NIC progress in the paper's
    lineage: move control flow next to the data and the per-message host
    wake-ups vanish).  The fused whole-solve program
    (:mod:`repro.solve.fused`) pays instead ONE trace+compile ``t_trace`` at
    first use plus a single ``t_launch``, after which every iteration runs
    inside one ``lax.while_loop`` with zero host involvement.

    Attributes:
      t_launch: per-dispatch host overhead, seconds (Python -> runtime ->
        device doorbell round-trip; ~tens of microseconds).
      t_trace: one-time trace + XLA-compile cost of the fused whole-solve
        program, seconds (amortized by the fused-program cache across
        solves with the same (pattern, strategy, codec, dtype) key).
    """

    t_launch: float = 50e-6
    t_trace: float = 25e-3


def launches_per_iter(
    matvecs_per_iter: float = 1.0,
    reductions_per_iter: float = 2.0,
    overlap: bool = False,
) -> float:
    """Host dispatches per host-driven solver iteration.

    A barrier matvec is two dispatches (halo exchange program, then the
    SpMV kernel); a split-phase matvec is five (remote-plan exchange,
    local-plan exchange, interior SpMV, halo merge, boundary SpMV) -- the
    overlap that hides wire time on device costs extra host launches.  Every
    hierarchical dot product is one more jitted collective dispatch.
    """
    per_matvec = 5.0 if overlap else 2.0
    return matvecs_per_iter * per_matvec + reductions_per_iter


def predict_solver(
    machine: MachineParams,
    strategy: Strategy,
    transport: Transport,
    stats: PatternStats,
    iters: int,
    reductions_per_iter: float = 2.0,
    t_interior: float = 0.0,
    t_boundary: float = 0.0,
    overlap: bool = False,
    setup_stats: Optional[PatternStats] = None,
    fused: Optional[bool] = None,
    launch: Optional[LaunchModel] = None,
    matvecs_per_iter: float = 1.0,
) -> Tuple[float, float, float]:
    """(setup, per-iteration, total) time of an ``iters``-iteration solve.

    ``total = setup + iters * (T_step + reductions_per_iter * T_red)`` where
    ``T_step`` is the Table 6 composite plus compute (barrier) or
    :func:`predict_overlapped` (split-phase), and ``setup`` is
    :func:`predict_setup` evaluated on ``setup_stats`` (defaults to
    ``stats``; pass the unwidened stats when ``stats`` is payload-widened).

    ``fused`` selects the execution front-end modeled by ``launch`` (a
    :class:`LaunchModel`): ``None`` (default) models communication and
    compute only -- the paper's launch-overhead-free accounting, byte-
    identical to the pre-fusion model; ``False`` charges the host-driven
    loop ``t_launch`` per dispatch, :func:`launches_per_iter` dispatches per
    iteration; ``True`` charges the fused whole-solve program one
    ``t_trace + t_launch`` up front and nothing per iteration.  The
    crossover ``iters ~ t_trace / (launches * t_launch)`` is what
    ``advise_solver(fused="auto")`` exposes.
    """
    if iters < 1:
        raise ValueError(f"iters must be >= 1, got {iters}")
    setup = predict_setup(machine, strategy, transport, setup_stats or stats)
    if overlap:
        step = predict_overlapped(
            machine, strategy, transport, stats, t_interior, t_boundary
        )
    else:
        step = predict(machine, strategy, transport, stats) + t_interior + t_boundary
    per_iter = step + reductions_per_iter * predict_reduction(machine, stats)
    if fused is not None:
        lm = launch if launch is not None else LaunchModel()
        if fused:
            setup += lm.t_trace + lm.t_launch
        else:
            per_iter += lm.t_launch * launches_per_iter(
                matvecs_per_iter, reductions_per_iter, overlap
            )
    return setup, per_iter, setup + iters * per_iter


def predict_all(
    machine: MachineParams,
    stats: PatternStats,
    include_two_step_one: bool = False,
    wire: "WireModel | str | None" = None,
) -> Dict[Tuple[Strategy, Transport], float]:
    """Evaluate every modeled (strategy, transport) pair for one pattern."""
    out: Dict[Tuple[Strategy, Transport], float] = {}
    for strategy, transport in modeled_pairs(include_two_step_one):
        out[(strategy, transport)] = predict(
            machine, strategy, transport, stats, wire=wire
        )
    return out
