"""Hardware path-parameter registries (paper §3, Tables 2-4).

The paper measures postal-model parameters (``alpha`` latency [s], ``beta``
per-byte cost [s/B]) for every data-flow path on a Lassen node, split by the
physical locality of the two endpoints (on-socket / on-node / off-node), the
messaging protocol (short / eager / rendezvous), and the memory space
(CPU <-> CPU vs GPU <-> GPU), plus ``cudaMemcpyAsync`` staging-copy costs and
the NIC injection-bandwidth limit ``R_N``.

Two registries are provided:

* ``LASSEN`` -- the paper's measured values, verbatim from Tables 2, 3, 4.
  Used by the paper-figure reproduction benchmarks so that model outputs are
  exact reproductions of the paper's predictions.
* ``TPU_V5E_POD`` -- the TPU adaptation (DESIGN.md section 2).  The "node"
  becomes a 16x16-chip ICI pod; on-socket ~ 1-hop ICI, on-node ~ multi-hop
  ICI, off-node ~ inter-pod DCI; the staging copy becomes an HBM
  read/write bounce; the NIC injection limit becomes the per-pod DCI egress
  limit.  Values are spec-derived (no TPU hardware in this container) and
  clearly marked as such.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Dict, Tuple


class Locality(enum.Enum):
    """Relative location of two communicating endpoints (paper Fig 2.5)."""

    ON_SOCKET = "on-socket"
    ON_NODE = "on-node"
    OFF_NODE = "off-node"


class Protocol(enum.Enum):
    """MPI messaging protocol classes (paper §3)."""

    SHORT = "short"
    EAGER = "eager"
    RENDEZVOUS = "rendezvous"


class Space(enum.Enum):
    """Memory space of the communicating endpoints."""

    CPU = "cpu"
    GPU = "gpu"  # on TPU: "device-direct" logical path


@dataclasses.dataclass(frozen=True)
class PathParams:
    """Postal-model parameters for one data-flow path: ``T = alpha + beta*s``."""

    alpha: float  # latency [s]
    beta: float  # inverse bandwidth [s/B]

    def time(self, nbytes: float) -> float:
        return self.alpha + self.beta * float(nbytes)


@dataclasses.dataclass(frozen=True)
class CopyParams:
    """Staging-copy parameters (paper Table 3): host<->device bounce."""

    h2d: PathParams
    d2h: PathParams


@dataclasses.dataclass(frozen=True)
class MachineParams:
    """Everything the paper's models need for one machine.

    Attributes:
      name: registry key.
      paths: ``(space, protocol, locality) -> PathParams`` (paper Table 2).
      copy: ``nprocs -> CopyParams`` for staged-through-host copies
        (paper Table 3; keys 1 and 4 on Lassen).
      rn_inv: inverse NIC/egress injection bandwidth ``1/R_N`` [s/B]
        (paper Table 4).
      gpus_per_socket: ``gps`` in eq. (4.1).
      sockets_per_node: 2 on Lassen; 1 for a TPU pod (flat ICI domain).
      procs_per_socket: ``pps`` in eq. (4.2).
      short_max / eager_max: protocol cutoffs in bytes (``short`` unused for
        GPU paths, as on Lassen).
    """

    name: str
    paths: Dict[Tuple[Space, Protocol, Locality], PathParams]
    copy: Dict[int, CopyParams]
    rn_inv: float
    gpus_per_socket: int
    sockets_per_node: int
    procs_per_socket: int
    short_max: int = 512
    eager_max: int = 65536

    # ------------------------------------------------------------------
    @property
    def gpus_per_node(self) -> int:
        return self.gpus_per_socket * self.sockets_per_node

    @property
    def procs_per_node(self) -> int:
        """PPN: maximum processes available for Split strategies."""
        return self.procs_per_socket * self.sockets_per_node

    @property
    def r_n(self) -> float:
        """NIC / pod-egress injection bandwidth [B/s]."""
        return 1.0 / self.rn_inv

    # ------------------------------------------------------------------
    def protocol_for(self, nbytes: float, space: Space) -> Protocol:
        """Pick the protocol class by message size (paper §3).

        The short protocol is not used for device-aware messages on Lassen;
        we keep that behaviour for every registry.
        """
        if space is Space.CPU and nbytes <= self.short_max:
            return Protocol.SHORT
        if nbytes <= self.eager_max:
            return Protocol.EAGER
        return Protocol.RENDEZVOUS

    def path(self, space: Space, locality: Locality, nbytes: float) -> PathParams:
        """Postal parameters for a message of ``nbytes`` over one path."""
        proto = self.protocol_for(nbytes, space)
        return self.paths[(space, proto, locality)]


# ---------------------------------------------------------------------------
# Lassen: measured parameters, verbatim from paper Tables 2, 3, 4.
# ---------------------------------------------------------------------------

_L = Locality
_P = Protocol
_S = Space

LASSEN = MachineParams(
    name="lassen",
    paths={
        # CPU, short                     on-socket      on-node       off-node
        (_S.CPU, _P.SHORT, _L.ON_SOCKET): PathParams(3.67e-07, 1.32e-10),
        (_S.CPU, _P.SHORT, _L.ON_NODE): PathParams(9.25e-07, 1.19e-09),
        (_S.CPU, _P.SHORT, _L.OFF_NODE): PathParams(1.89e-06, 6.88e-10),
        # CPU, eager
        (_S.CPU, _P.EAGER, _L.ON_SOCKET): PathParams(4.61e-07, 7.12e-11),
        (_S.CPU, _P.EAGER, _L.ON_NODE): PathParams(1.17e-06, 2.18e-10),
        (_S.CPU, _P.EAGER, _L.OFF_NODE): PathParams(2.44e-06, 3.79e-10),
        # CPU, rendezvous
        (_S.CPU, _P.RENDEZVOUS, _L.ON_SOCKET): PathParams(3.15e-06, 3.40e-11),
        (_S.CPU, _P.RENDEZVOUS, _L.ON_NODE): PathParams(6.77e-06, 1.49e-10),
        (_S.CPU, _P.RENDEZVOUS, _L.OFF_NODE): PathParams(7.76e-06, 7.97e-11),
        # GPU, eager (no short protocol for device-aware messages)
        (_S.GPU, _P.EAGER, _L.ON_SOCKET): PathParams(1.87e-06, 5.79e-11),
        (_S.GPU, _P.EAGER, _L.ON_NODE): PathParams(2.02e-05, 2.15e-10),
        (_S.GPU, _P.EAGER, _L.OFF_NODE): PathParams(8.95e-06, 1.72e-10),
        # GPU, rendezvous
        (_S.GPU, _P.RENDEZVOUS, _L.ON_SOCKET): PathParams(1.82e-05, 1.46e-11),
        (_S.GPU, _P.RENDEZVOUS, _L.ON_NODE): PathParams(1.93e-05, 2.39e-11),
        (_S.GPU, _P.RENDEZVOUS, _L.OFF_NODE): PathParams(1.10e-05, 1.72e-10),
    },
    copy={
        # paper Table 3: columns are (H2D, D2H)
        1: CopyParams(h2d=PathParams(1.30e-05, 1.85e-11), d2h=PathParams(1.27e-05, 1.96e-11)),
        4: CopyParams(h2d=PathParams(1.52e-05, 5.52e-10), d2h=PathParams(1.47e-05, 1.50e-10)),
    },
    rn_inv=4.19e-11,  # paper Table 4, inter-CPU
    gpus_per_socket=2,
    sockets_per_node=2,
    procs_per_socket=20,
)


# ---------------------------------------------------------------------------
# TPU v5e pod: spec-derived adaptation (DESIGN.md section 2).
# ---------------------------------------------------------------------------

# Roofline constants (also used by benchmarks/bench_roofline.py).
TPU_V5E_PEAK_BF16_FLOPS = 197e12  # [FLOP/s] per chip
TPU_V5E_HBM_BW = 819e9  # [B/s] per chip
TPU_V5E_ICI_LINK_BW = 50e9  # [B/s] per ICI link (assignment constant)
TPU_V5E_HBM_BYTES = 16 * 2**30  # 16 GiB HBM per chip
TPU_V5E_VMEM_BYTES = 128 * 2**20  # ~128 MiB VMEM per chip

_ICI_HOP_LAT = 1.0e-06  # [s] per ICI hop incl. software overhead
_ICI_BETA = 1.0 / TPU_V5E_ICI_LINK_BW  # 2.0e-11 s/B on the contended link
_DCI_LAT = 1.0e-05  # [s] inter-pod (data-center network)
_DCI_CHIP_BW = 6.25e9  # [B/s] per-chip share of pod egress (50 Gb/s)
_POD_EGRESS_BW = 4.0e11  # [B/s] total pod egress ("NIC" analogue, 400 GB/s)
_HBM_BETA = 1.0 / TPU_V5E_HBM_BW

TPU_V5E_POD = MachineParams(
    name="tpu_v5e_pod",
    paths={
        # "CPU" space = staged/fused logical path over the fabric.
        # on-socket ~ 1-hop ICI neighbour, on-node ~ multi-hop intra-pod ICI
        # (mean 8 hops on a 16x16 torus), off-node ~ inter-pod DCI.
        (_S.CPU, _P.SHORT, _L.ON_SOCKET): PathParams(_ICI_HOP_LAT, _ICI_BETA),
        (_S.CPU, _P.SHORT, _L.ON_NODE): PathParams(8 * _ICI_HOP_LAT, _ICI_BETA),
        (_S.CPU, _P.SHORT, _L.OFF_NODE): PathParams(_DCI_LAT, 1.0 / _DCI_CHIP_BW),
        (_S.CPU, _P.EAGER, _L.ON_SOCKET): PathParams(_ICI_HOP_LAT, _ICI_BETA),
        (_S.CPU, _P.EAGER, _L.ON_NODE): PathParams(8 * _ICI_HOP_LAT, _ICI_BETA),
        (_S.CPU, _P.EAGER, _L.OFF_NODE): PathParams(_DCI_LAT, 1.0 / _DCI_CHIP_BW),
        (_S.CPU, _P.RENDEZVOUS, _L.ON_SOCKET): PathParams(2 * _ICI_HOP_LAT, _ICI_BETA),
        (_S.CPU, _P.RENDEZVOUS, _L.ON_NODE): PathParams(16 * _ICI_HOP_LAT, _ICI_BETA),
        (_S.CPU, _P.RENDEZVOUS, _L.OFF_NODE): PathParams(2 * _DCI_LAT, 1.0 / _DCI_CHIP_BW),
        # "GPU" space = device-direct logical send (un-fused XLA collective
        # over the joint mesh): same wires, higher per-message software cost
        # because each fine-grained message becomes its own collective step.
        (_S.GPU, _P.EAGER, _L.ON_SOCKET): PathParams(3 * _ICI_HOP_LAT, _ICI_BETA),
        (_S.GPU, _P.EAGER, _L.ON_NODE): PathParams(12 * _ICI_HOP_LAT, _ICI_BETA),
        (_S.GPU, _P.EAGER, _L.OFF_NODE): PathParams(2 * _DCI_LAT, 1.0 / _DCI_CHIP_BW),
        (_S.GPU, _P.RENDEZVOUS, _L.ON_SOCKET): PathParams(6 * _ICI_HOP_LAT, _ICI_BETA),
        (_S.GPU, _P.RENDEZVOUS, _L.ON_NODE): PathParams(24 * _ICI_HOP_LAT, _ICI_BETA),
        (_S.GPU, _P.RENDEZVOUS, _L.OFF_NODE): PathParams(3 * _DCI_LAT, 1.0 / _DCI_CHIP_BW),
    },
    copy={
        # staging bounce = HBM read + write (DMA setup latency + 2x HBM beta)
        1: CopyParams(
            h2d=PathParams(2.0e-06, _HBM_BETA),
            d2h=PathParams(2.0e-06, _HBM_BETA),
        ),
        # sharded staging buffer read from 4 chips ("duplicate device
        # pointer" analogue): 4 concurrent DMA streams contending on HBM.
        4: CopyParams(
            h2d=PathParams(2.5e-06, 4 * _HBM_BETA),
            d2h=PathParams(2.5e-06, 2 * _HBM_BETA),
        ),
    },
    rn_inv=1.0 / _POD_EGRESS_BW,
    gpus_per_socket=256,  # chips per "socket" == chips per pod (flat domain)
    sockets_per_node=1,
    procs_per_socket=256,
    short_max=512,
    eager_max=65536,
)


REGISTRY: Dict[str, MachineParams] = {
    LASSEN.name: LASSEN,
    TPU_V5E_POD.name: TPU_V5E_POD,
}


def get_machine(name: str) -> MachineParams:
    try:
        return REGISTRY[name]
    except KeyError as e:
        raise KeyError(f"unknown machine {name!r}; known: {sorted(REGISTRY)}") from e
