"""Irregular point-to-point communication pattern descriptors.

A :class:`CommPattern` is the setup-time description of "who sends how many
bytes to whom" -- the input both to the performance models (via
:meth:`CommPattern.stats`, computing the paper's Table 7 parameters) and to
the strategy planners in :mod:`repro.core.split_plan` / :mod:`repro.comm`.

Ranks are global process/chip ids; the node (pod) of a rank is
``rank // ppn``.  This mirrors the paper's SpMV setting where GPU ``i`` holds
row block ``i`` and the pattern is induced by the off-diagonal sparsity.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict, Iterable, List, Mapping, Tuple

from repro.core.perfmodel import PatternStats


@dataclasses.dataclass(frozen=True)
class Message:
    src: int
    dst: int
    nbytes: int

    def __post_init__(self) -> None:
        if self.nbytes < 0:
            raise ValueError("message size must be non-negative")


@dataclasses.dataclass(frozen=True)
class CommPattern:
    """A static irregular communication pattern over ``nranks`` ranks."""

    nranks: int
    ppn: int  # ranks per node (chips per pod)
    messages: Tuple[Message, ...]

    # ------------------------------------------------------------------
    @staticmethod
    def from_messages(nranks: int, ppn: int, messages: Iterable[Message | Tuple[int, int, int]]) -> "CommPattern":
        msgs = tuple(m if isinstance(m, Message) else Message(*m) for m in messages)
        for m in msgs:
            if not (0 <= m.src < nranks and 0 <= m.dst < nranks):
                raise ValueError(f"message {m} out of range for nranks={nranks}")
        return CommPattern(nranks=nranks, ppn=ppn, messages=msgs)

    # ------------------------------------------------------------------
    @property
    def nnodes(self) -> int:
        return (self.nranks + self.ppn - 1) // self.ppn

    def node_of(self, rank: int) -> int:
        return rank // self.ppn

    def local_rank(self, rank: int) -> int:
        return rank % self.ppn

    # ------------------------------------------------------------------
    def inter_node_messages(self) -> List[Message]:
        return [m for m in self.messages if self.node_of(m.src) != self.node_of(m.dst)]

    def recv_lists(self) -> Dict[int, List[Message]]:
        """Per-destination-rank receive lists (Algorithm 1 input ``l_recv``)."""
        out: Dict[int, List[Message]] = defaultdict(list)
        for m in self.messages:
            out[m.dst].append(m)
        return dict(out)

    # ------------------------------------------------------------------
    def stats(self) -> PatternStats:
        """Compute the paper's Table 7 parameters for this pattern.

        All parameters are worst-case ("max over ...") as in the paper, since
        the measured quantity is the max time over any single process.

        Byte terms are per element; for batched ``k``-column payloads widen
        the result via :meth:`~repro.core.perfmodel.PatternStats.widened`
        (or pass ``payload_width`` to the advisor, the single widening entry
        point -- widening both here and there would scale bytes by ``k**2``).
        """
        bytes_by_src: Dict[int, int] = defaultdict(int)
        msgs_by_src: Dict[int, int] = defaultdict(int)
        bytes_injected_by_node: Dict[int, int] = defaultdict(int)
        bytes_by_node_pair: Dict[Tuple[int, int], int] = defaultdict(int)
        msgs_by_node_pair: Dict[Tuple[int, int], int] = defaultdict(int)
        dest_nodes_by_src: Dict[int, set] = defaultdict(set)
        dest_nodes_by_node: Dict[int, set] = defaultdict(set)

        for m in self.inter_node_messages():
            sn, dn = self.node_of(m.src), self.node_of(m.dst)
            bytes_by_src[m.src] += m.nbytes
            msgs_by_src[m.src] += 1
            bytes_injected_by_node[sn] += m.nbytes
            bytes_by_node_pair[(sn, dn)] += m.nbytes
            msgs_by_node_pair[(sn, dn)] += 1
            dest_nodes_by_src[m.src].add(dn)
            dest_nodes_by_node[sn].add(dn)

        def _max(d: Mapping, default=0):
            return max(d.values()) if d else default

        return PatternStats(
            s_proc=float(_max(bytes_by_src)),
            s_node=float(_max(bytes_injected_by_node)),
            s_node_node=float(_max(bytes_by_node_pair)),
            m_proc_node=int(_max({k: len(v) for k, v in dest_nodes_by_src.items()})),
            m_node_node=int(_max(msgs_by_node_pair)),
            m_proc=int(_max(msgs_by_src)),
            num_dest_nodes=int(_max({k: len(v) for k, v in dest_nodes_by_node.items()})),
        )


# ---------------------------------------------------------------------------
# Scenario generators (paper §4.6, Fig 4.3)
# ---------------------------------------------------------------------------


def figure43_pattern(
    nbytes_per_msg: int,
    n_inter_node_msgs: int,
    n_dest_nodes: int,
    ppn: int = 4,
) -> CommPattern:
    """The Fig 4.3 scenario: one node sends ``n_inter_node_msgs`` messages of
    ``nbytes_per_msg`` bytes, spread evenly over its on-node GPUs, to
    ``n_dest_nodes`` destination nodes (round-robin over destination ranks).
    """
    nranks = (n_dest_nodes + 1) * ppn
    msgs = []
    for i in range(n_inter_node_msgs):
        src = i % ppn  # node 0 ranks
        dnode = 1 + (i % n_dest_nodes)
        dst = dnode * ppn + (i // n_dest_nodes) % ppn
        msgs.append(Message(src, dst, nbytes_per_msg))
    return CommPattern.from_messages(nranks, ppn, msgs)
